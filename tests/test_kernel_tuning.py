"""Equivalence + planner tests for the vectorized (chunked) Pallas kernels.

The chunked kernels must compute exactly what the pre-refactor rank-1
kernels computed: the streamed square-form contraction of
``core.matmul.pm_matmul_scan``.  Integer paths bit-match; float paths match
to reassociation tolerance (chunking changes the add order, nothing else).
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.matmul import pm_matmul_scan
from repro.kernels import ops, tuning

RNG = np.random.default_rng(11)

RAGGED_SHAPES = [(1, 1, 1), (7, 13, 9), (100, 60, 130), (64, 128, 32),
                 (130, 257, 140)]

# (bm, bn, bk, kc) plans: degenerate 1-chunk (kc == bk), rank-1 (kc == 1),
# and mid chunkings, across both PM-block layouts.
PLANS = [
    dict(bm=32, bn=128, bk=32, kc=32, pm_layout="mnk"),    # 1-chunk
    dict(bm=32, bn=128, bk=32, kc=32, pm_layout="mkn"),    # 1-chunk, TPU lay
    dict(bm=64, bn=128, bk=128, kc=1, pm_layout="mkn"),    # rank-1 (seed)
    dict(bm=64, bn=128, bk=128, kc=32, pm_layout="mnk"),
    dict(bm=8, bn=128, bk=64, kc=16, pm_layout="mkn"),
]


@pytest.mark.parametrize("shape", RAGGED_SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_chunked_matches_pm_matmul_scan(shape, dtype):
    m, k, n = shape
    if dtype == "int8":
        a = jnp.asarray(RNG.integers(-128, 128, (m, k)).astype(np.int8))
        b = jnp.asarray(RNG.integers(-128, 128, (k, n)).astype(np.int8))
    else:
        a = jnp.asarray(RNG.normal(size=(m, k)), jnp.dtype(dtype))
        b = jnp.asarray(RNG.normal(size=(k, n)), jnp.dtype(dtype))
    out = np.asarray(ops.sq_matmul(a, b))
    ref = np.asarray(pm_matmul_scan(a, b))
    if dtype == "int8":
        np.testing.assert_array_equal(out, ref)       # bit-exact
    else:
        np.testing.assert_allclose(out, ref, rtol=5e-3, atol=5e-3 * k)


@pytest.mark.parametrize("plan", PLANS)
def test_plans_agree_f32(plan):
    a = jnp.asarray(RNG.normal(size=(100, 200)).astype(np.float32))
    b = jnp.asarray(RNG.normal(size=(200, 60)).astype(np.float32))
    out = np.asarray(ops.sq_matmul(a, b, **plan))
    ref = np.asarray(pm_matmul_scan(a, b))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-2)


@pytest.mark.parametrize("plan", PLANS)
def test_plans_agree_int8_bitexact(plan):
    a = jnp.asarray(RNG.integers(-128, 128, (50, 70)).astype(np.int8))
    b = jnp.asarray(RNG.integers(-128, 128, (70, 40)).astype(np.int8))
    out = np.asarray(ops.sq_matmul(a, b, **plan))
    np.testing.assert_array_equal(out, np.asarray(pm_matmul_scan(a, b)))


@pytest.mark.parametrize("kind", ["cpm3_matmul", "cpm4_matmul"])
@pytest.mark.parametrize("kc,pm_layout", [(1, "mkn"), (64, "mkn"),
                                          (16, "mnk"), (64, "mnk")])
def test_cpm_chunked_layouts_agree(kind, kc, pm_layout):
    m, k, n = 40, 64, 24
    x = jnp.asarray((RNG.normal(size=(m, k))
                     + 1j * RNG.normal(size=(m, k))).astype(np.complex64))
    y = jnp.asarray((RNG.normal(size=(k, n))
                     + 1j * RNG.normal(size=(k, n))).astype(np.complex64))
    op = getattr(ops, kind)
    re, im = op(x, y, bk=64, kc=kc, pm_layout=pm_layout)
    z = np.asarray(x) @ np.asarray(y)
    np.testing.assert_allclose(np.asarray(re), z.real, rtol=1e-3, atol=1e-3 * k)
    np.testing.assert_allclose(np.asarray(im), z.imag, rtol=1e-3, atol=1e-3 * k)


@pytest.mark.parametrize("tb", [1, 4, 16])
def test_conv_tap_blocks_agree(tb):
    x = jnp.asarray(RNG.normal(size=(500,)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(11,)).astype(np.float32))   # ragged vs tb
    out = np.asarray(ops.sq_conv(x, w, tb=tb))
    ref = np.correlate(np.asarray(x), np.asarray(w), mode="valid")
    np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("shape", [(16, 16, 3, 3), (32, 24, 5, 3),
                                   (64, 64, 7, 7)])
def test_sq_conv2d_matches_lax_conv(shape):
    import jax.lax as lax
    H, W, kh, kw = shape
    x = jnp.asarray(RNG.normal(size=(H, W)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(kh, kw)).astype(np.float32))
    out = np.asarray(ops.sq_conv2d(x, w))
    ref = lax.conv_general_dilated(
        x[None, None], w[None, None], (1, 1), "VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))[0, 0]
    np.testing.assert_allclose(out, np.asarray(ref), rtol=2e-3,
                               atol=2e-3 * kh * kw)


def test_sq_conv2d_filter_bank():
    import jax.lax as lax
    x = jnp.asarray(RNG.normal(size=(20, 20)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(4, 3, 3)).astype(np.float32))
    out = np.asarray(ops.sq_conv2d(x, w))
    ref = lax.conv_general_dilated(
        x[None, None], w[:, None], (1, 1), "VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))[0]
    assert out.shape == (4, 18, 18)
    np.testing.assert_allclose(out, np.asarray(ref), rtol=2e-3, atol=2e-2)


# ---------------------------------------------------------------------------
# Planner unit tests
# ---------------------------------------------------------------------------

def test_planner_sublane_alignment():
    """Odd M must not yield a sublane-misaligned bm (the seed bug: M=100
    -> bm=100)."""
    plan = tuning.plan_matmul(100, 256, 256)
    assert plan.bm % tuning.SUBLANE == 0
    plan = tuning.plan_matmul(100, 256, 256, bm=100)    # explicit odd bm
    assert plan.bm % tuning.SUBLANE == 0


def test_planner_small_operands_exact():
    plan = tuning.plan_matmul(3, 5, 2)
    assert plan.bm <= 3 and plan.bn <= 5 and plan.bk <= 2
    assert plan.bk % plan.kc == 0


def test_planner_kc_divides_bk():
    for (m, n, k) in [(128, 128, 128), (1000, 333, 77), (8, 8, 8)]:
        for layout in ("mkn", "mnk"):
            plan = tuning.plan_matmul(m, n, k, pm_layout=layout)
            assert plan.bk % plan.kc == 0, plan


def test_planner_explicit_tiles_respected():
    plan = tuning.plan_matmul(512, 512, 512, bm=64, bn=128, bk=128, kc=16)
    assert (plan.bm, plan.bn, plan.bk, plan.kc) == (64, 128, 128, 16)


def test_planner_mnk_cache_budget():
    """mnk plans cap the reduce depth and keep the hot (bn, kc) panel
    cache-resident (the chunk-wide bound was retired: large-bm single-step
    plans are the measured winners on tall-skinny im2col shapes)."""
    for plan in tuning.candidate_plans(1024, 1024, 1024, pm_layout="mnk"):
        if plan.kc > 1:
            assert plan.kc <= tuning.KC_MNK_MAX
            assert (plan.bn + tuning.SUBLANE) * plan.kc * 4 \
                <= tuning.CACHE_BUDGET


def test_planner_vmem_budget():
    from repro.core import cost_model as cm
    for plan in tuning.candidate_plans(2048, 2048, 2048):
        cost = cm.pm_grid_cost(2048, 2048, 2048, *plan.astuple())
        assert cost.vmem_bytes <= tuning.VMEM_BUDGET


def test_autotune_cache_roundtrip(tmp_path, monkeypatch):
    """plan_matmul must serve plans straight from a JSON cache file."""
    path = tmp_path / "tuning_cache.json"
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(path))
    tuning.clear_cache()
    entry = {"bm": 16, "bn": 128, "bk": 64, "kc": 16, "pm_layout": "mnk",
             "us_per_call": 1.0}
    path.write_text(json.dumps({"sq_matmul:64x64x64:float32": entry}))
    plan = tuning.plan_matmul(64, 64, 64, jnp.float32, pm_layout="mnk")
    assert plan == tuning.TilePlan(16, 128, 64, 16, "mnk")
    # a layout mismatch must NOT serve the cached plan (CPU-tuned "mnk"
    # entries never leak into TPU "mkn" plans)
    plan = tuning.plan_matmul(64, 64, 64, jnp.float32, pm_layout="mkn")
    assert plan.pm_layout == "mkn" and plan.bm != 16
    # explicit user tiles bypass the cache
    plan = tuning.plan_matmul(64, 64, 64, jnp.float32, bm=32,
                              pm_layout="mnk")
    assert plan.bm == 32
    tuning.clear_cache()


def test_autotune_sweep_smoke(tmp_path, monkeypatch):
    """End-to-end: autotune a tiny shape, then plan from the cache."""
    path = tmp_path / "cache.json"
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(path))
    tuning.clear_cache()
    cache = tuning.autotune_matmul([(32, 32, 32)], jnp.float32,
                                   max_candidates=2, reps=1)
    key = "sq_matmul:32x32x32:float32"
    assert key in cache and cache[key]["us_per_call"] > 0
    plan = tuning.plan_matmul(32, 32, 32, jnp.float32,
                              pm_layout=cache[key]["pm_layout"])
    assert plan.bm == cache[key]["bm"] and plan.kc == cache[key]["kc"]
    tuning.clear_cache()


def test_autotune_miss_warns_once(tmp_path, monkeypatch):
    """On a cache miss the planner warns ONCE per key and falls back to the
    cost-model plan (no silent per-call sweeping)."""
    import warnings

    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "none.json"))
    tuning.clear_cache()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        p1 = tuning.plan_matmul(37, 41, 43, jnp.float32, pm_layout="mnk")
        assert len(w) == 1 and "autotune cache miss" in str(w[0].message)
        p2 = tuning.plan_matmul(37, 41, 43, jnp.float32, pm_layout="mnk")
        assert len(w) == 1                       # warned once, not twice
    assert p1 == p2                              # deterministic model plan
    # explicit tiles never consult the cache, so they never warn
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        tuning.plan_matmul(37, 41, 43, jnp.float32, bm=8, bn=128, bk=128)
        assert len(w) == 0
    tuning.clear_cache()


def test_autotune_escape_hatch(tmp_path, monkeypatch):
    """REPRO_AUTOTUNE=0 disables cache lookups AND the miss warning."""
    import warnings

    path = tmp_path / "cache.json"
    entry = {"bm": 16, "bn": 128, "bk": 64, "kc": 16, "pm_layout": "mnk",
             "us_per_call": 1.0}
    path.write_text(json.dumps({"sq_matmul:64x64x64:float32": entry}))
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(path))
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    tuning.clear_cache()
    assert not tuning.autotune_enabled()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        plan = tuning.plan_matmul(64, 64, 64, jnp.float32, pm_layout="mnk")
        assert len(w) == 0                       # no miss warning
    assert plan != tuning.TilePlan(16, 128, 64, 16, "mnk")   # cache ignored
    tuning.clear_cache()


# The training bench's GEMM population (benchmarks/training.py model at
# B=2, S=64): forward ffn pair at M = B*S*2 rows after attn concat, the
# TRANSPOSED backward pair the custom VJP emits for dL/dx / dL/dW, and
# the chunked vocab-grad trio (loss_chunk rows t=64 against v=4096).
TRAINING_GEMMS = [           # (m, n, k), matching plan_matmul's order
    (256, 256, 128),      # qkv/out fwd + bwd_x (square d_model block)
    (256, 1024, 128),     # ffn up bwd pair
    (1024, 256, 128),     # ffn down bwd pair (transposed partner)
    (64, 4096, 256),      # chunked logits fwd (t x d @ d x v)
    (64, 256, 4096),      # logits bwd_x (t x v @ v x d)
    (4096, 256, 64),      # logits bwd_w (v x t @ t x d, transposed)
]


def test_training_shapes_served_from_committed_cache(monkeypatch):
    """Every training-bench GEMM (forward, transposed-backward pair, and
    vocab-grad trio) must hit the COMMITTED package cache: plan_matmul
    serves the tuned plan with zero miss warnings, so a square_pallas
    train step traces warning-free out of the box."""
    import warnings

    monkeypatch.delenv("REPRO_TUNING_CACHE", raising=False)
    tuning.clear_cache()
    cache = tuning.load_cache()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        for (m, n, k) in TRAINING_GEMMS:
            key = f"sq_matmul:{m}x{n}x{k}:float32"
            assert key in cache, f"committed cache missing {key}"
            entry = cache[key]
            plan = tuning.plan_matmul(m, n, k, jnp.float32,
                                      pm_layout=entry["pm_layout"])
            assert plan == tuning.TilePlan(
                entry["bm"], entry["bn"], entry["bk"], entry["kc"],
                entry["pm_layout"]), key
    misses = [str(x.message) for x in w if "cache miss" in str(x.message)]
    assert not misses, misses
    tuning.clear_cache()


@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_batched_kernel_matches_unbatched(dtype):
    """The leading batch grid axis computes exactly the per-element 2D
    kernel result (same plan family, same arithmetic)."""
    nb, m, k, n = 3, 33, 40, 17
    if dtype == "int8":
        a = jnp.asarray(RNG.integers(-128, 128, (nb, m, k)).astype(np.int8))
        b = jnp.asarray(RNG.integers(-128, 128, (nb, k, n)).astype(np.int8))
    else:
        a = jnp.asarray(RNG.normal(size=(nb, m, k)).astype(np.float32))
        b = jnp.asarray(RNG.normal(size=(nb, k, n)).astype(np.float32))
    out = np.asarray(ops.sq_matmul(a, b))
    assert out.shape == (nb, m, n)
    for i in range(nb):
        ref = np.asarray(ops.sq_matmul(a[i], b[i]))
        if dtype == "int8":
            np.testing.assert_array_equal(out[i], ref)
        else:
            np.testing.assert_allclose(out[i], ref, rtol=1e-5, atol=1e-4)


def test_autotune_batched_writes_batch_key(tmp_path, monkeypatch):
    """autotune_matmul(batch=N) writes the batch-keyed entry that
    plan_matmul(batch=N) looks up (closing the miss-warning loop)."""
    path = tmp_path / "cache.json"
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(path))
    tuning.clear_cache()
    cache = tuning.autotune_matmul([(16, 16, 16)], jnp.float32,
                                   max_candidates=1, reps=1, batch=3)
    key = "sq_matmul:3b:16x16x16:float32"
    assert key in cache
    plan = tuning.plan_matmul(16, 16, 16, jnp.float32, batch=3,
                              pm_layout=cache[key]["pm_layout"])
    assert plan.bm == cache[key]["bm"] and plan.kc == cache[key]["kc"]
    tuning.clear_cache()
