"""End-to-end square-routed training (the custom VJP under a real
optimizer loop): fixed-seed loss equivalence vs the multiplier baseline,
backward square-coverage acceptance, and guarded degradation in backward.

Companion to tests/test_vjp_square.py (per-contraction gradcheck) -- here
the unit is a full jitted train step: forward, custom-VJP backward, and
AdamW, over the deterministic synthetic pipeline (both modes consume
bit-identical batch streams, see SyntheticLM.take).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import counting, guards
from repro.core.einsum import fs_einsum
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.kernels import routing
from repro.models.lm import build_model
from repro.optim import adamw
from repro.train import step as step_mod
from repro.train.trainer import Trainer, TrainerConfig

RNG = np.random.default_rng(17)
N_STEPS = 3


def _cfg(mode):
    return ModelConfig(
        name="tiny-train", family="dense", n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=64, vocab=128, head_dim=16,
        dtype="float32", scan_layers=False, remat="none", attn_chunk_q=16,
        attn_chunk_kv=16, loss_chunk=16, max_seq=64, matmul_mode=mode)


def _setup(mode):
    cfg = _cfg(mode)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.adamw_init(params)
    data = SyntheticLM(DataConfig(global_batch=2, seq_len=32,
                                  vocab=cfg.vocab, seed=5), cfg)
    step = jax.jit(step_mod.make_train_step(model, step_mod.TrainConfig()))
    return step, params, opt, data.take(N_STEPS)


def _run(mode):
    step, params, opt, batches = _setup(mode)
    losses = []
    for batch in batches:
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(np.asarray(metrics["loss"])))
    jax.block_until_ready(params)
    return losses, params


def test_loss_trajectory_square_matches_standard():
    """N fixed-seed AdamW steps: the square-routed trajectory tracks the
    multiplier baseline to reassociation tolerance (the square route
    changes the add order of every contraction, forward and backward --
    nothing else)."""
    std, _ = _run("standard")
    sq, _ = _run("square_virtual")
    assert np.isfinite(std).all() and np.isfinite(sq).all()
    np.testing.assert_allclose(sq, std, rtol=2e-3, atol=2e-3)


def test_fixed_seed_square_run_is_deterministic():
    """Two identical square-routed runs are BIT-identical (the trajectory
    fingerprint BENCH_training.json tracks is stable on one host)."""
    l1, p1 = _run("square_virtual")
    l2, p2 = _run("square_virtual")
    assert adamw.tree_fingerprint(np.asarray(l1, np.float32)) == \
        adamw.tree_fingerprint(np.asarray(l2, np.float32))
    assert adamw.tree_fingerprint(p1) == adamw.tree_fingerprint(p2)


def test_train_step_backward_fraction_90pct():
    """Acceptance: a square_virtual train step square-routes >= 90% of
    its TOTAL contraction FLOPs AND >= 90% of backward volume -- the
    custom VJP's ``.bwd_x`` / ``.bwd_w`` sites are first-class audit
    entries, captured from the first (tracing) jitted call."""
    step, params, opt, batches = _setup("square_virtual")
    (p1, _, metrics), ctr = step_mod.audit_step(step, params, opt,
                                                batches[0])
    assert bool(np.isfinite(np.asarray(metrics["loss"])))
    assert ctr.total_mults > 0 and ctr.bwd_mults > 0
    assert ctr.fraction_square >= 0.9
    assert ctr.fraction_square_bwd >= 0.9
    sites = set(ctr.by_site())
    assert any(s.endswith(".bwd_x") for s in sites)
    assert any(s.endswith(".bwd_w") for s in sites)


def test_trainer_surfaces_backward_audit(tmp_path):
    """The Trainer's first-step audit lands in the run result with
    backward coverage visible (the production observability hook)."""
    cfg = _cfg("square_virtual")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.adamw_init(params)
    data = SyntheticLM(DataConfig(global_batch=2, seq_len=32,
                                  vocab=cfg.vocab, seed=5), cfg)
    step = jax.jit(step_mod.make_train_step(model, step_mod.TrainConfig()))
    trainer = Trainer(TrainerConfig(total_steps=2, ckpt_every=100,
                                    ckpt_dir=str(tmp_path), log_every=1),
                      step, params, opt, data)
    result = trainer.run()
    audit = result["contraction_audit"]
    assert audit is not None
    assert audit["fraction_square"] >= 0.9
    assert audit["fraction_square_bwd"] >= 0.9
    assert audit["bwd_mults"] > 0


def test_guard_trip_in_backward_demotes_and_completes():
    """Chaos case: a backward contraction whose square route saturates
    (cotangent ~1e22, so the materialized ``(g+w)^2`` is inf in f32)
    under an enabled guard must complete the step on the standard route
    -- gradients finite and correct, the demotion audit-visible on the
    ``.bwd_*`` site -- without poisoning the forward site.  Uses
    ``square_exact``: the PM-datapath emulation actually squares, so it
    has the saturation regime (``square_virtual`` cancels the
    corrections algebraically and cannot trip here)."""
    routing.reset_route_health()
    x = jnp.asarray(RNG.normal(size=(8, 16)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(16, 4)).astype(np.float32))

    def loss(x):
        out = fs_einsum("mk,kn->mn", x, w, mode="square_exact",
                        site="chaos")
        return jnp.sum(out) * 1e22          # backward cotangent ~1e22

    try:
        with guards.guarded(trip_limit=1):
            with counting.track_contractions() as ctr:
                dx = jax.grad(loss)(x)      # eager: the guard can fire
        assert bool(jnp.isfinite(dx).all())
        # the demoted backward result IS the standard-route gradient
        ref = jax.grad(lambda x: jnp.sum(jnp.einsum("mk,kn->mn", x, w))
                       * 1e22)(x)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(ref),
                                   rtol=1e-5)
        demoted = ctr.demoted_sites()
        assert any(s.startswith("chaos.bwd_") for s in demoted)
        assert "chaos" not in demoted       # forward site untouched
        modes = {r.site: (r.mode, r.demoted) for r in ctr.records}
        assert modes["chaos"] == ("square_exact", False)
    finally:
        routing.reset_route_health()


def test_guard_trip_does_not_leak_into_next_run():
    """After reset_route_health a fresh square-routed backward at sane
    magnitudes serves square again (no sticky demotion across tests)."""
    routing.reset_route_health()
    x = jnp.asarray(RNG.normal(size=(4, 8)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(8, 4)).astype(np.float32))
    loss = lambda x: jnp.sum(fs_einsum("mk,kn->mn", x, w,
                                       mode="square_virtual", site="chaos"))
    with guards.guarded(trip_limit=1):
        with counting.track_contractions() as ctr:
            jax.grad(loss)(x)
    assert ctr.demoted_sites() == []
    assert ctr.fraction_square == 1.0
