"""Paper §5 IIR extension + CPM4 Pallas kernel sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.conv import iir_filter
from repro.kernels import ops

RNG = np.random.default_rng(13)


def _iir_ref(x, b, a):
    nb, na = len(b), len(a)
    y = np.zeros(len(x))
    xp = np.pad(x, (nb - 1, 0))
    for t in range(len(x)):
        y[t] = np.dot(b[::-1], xp[t:t + nb])
        for j in range(na):
            if t - j - 1 >= 0:
                y[t] += a[j] * y[t - j - 1]
    return y


@pytest.mark.parametrize("nb,na", [(3, 1), (4, 2), (8, 3)])
def test_iir_square_matches_reference(nb, na):
    x = RNG.normal(size=(50,)).astype(np.float32)
    b = (RNG.normal(size=(nb,)) * 0.5).astype(np.float32)
    a = (RNG.normal(size=(na,)) * 0.3).astype(np.float32)   # stable-ish
    ref = _iir_ref(x, b, a)
    for mode in ("standard", "square"):
        out = np.asarray(iir_filter(jnp.asarray(x), jnp.asarray(b),
                                    jnp.asarray(a), mode=mode))
        np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("shape", [(4, 6, 5), (20, 30, 10), (64, 128, 32)])
def test_cpm4_kernel_sweep(shape):
    m, k, n = shape
    x = (RNG.normal(size=(m, k)) + 1j * RNG.normal(size=(m, k))).astype(np.complex64)
    y = (RNG.normal(size=(k, n)) + 1j * RNG.normal(size=(k, n))).astype(np.complex64)
    re, im = ops.cpm4_matmul(jnp.asarray(x), jnp.asarray(y))
    z = x @ y
    np.testing.assert_allclose(np.asarray(re), z.real, rtol=1e-3, atol=1e-3 * k)
    np.testing.assert_allclose(np.asarray(im), z.imag, rtol=1e-3, atol=1e-3 * k)
