"""Approximate-squaring study (paper conclusion + paper ref [1]).

Error of the square-based matmul when built from TRUNCATED squarers, as a
function of dropped low bits, plus the additional area saving the truncation
buys (partial-product rows removed from the squarer array).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import matmul as M


def approx_matmul_error(sizes=((64, 64, 64), (256, 256, 256)),
                        bits=(0, 2, 4, 6)):
    rng = np.random.default_rng(0)
    rows = []
    for (m, k, n) in sizes:
        a = rng.integers(-128, 128, (m, k)).astype(np.int8)
        b = rng.integers(-128, 128, (k, n)).astype(np.int8)
        exact = a.astype(np.int64) @ b.astype(np.int64)
        scale = np.abs(exact).mean() + 1e-9
        for db in bits:
            out = np.asarray(M.pm_matmul_approx(jnp.asarray(a), jnp.asarray(b),
                                                drop_bits=db))
            err = np.abs(out.astype(np.int64) - exact).mean() / scale
            # truncated squarer area: ~ (n-db)^2/2 of exact n^2/2 (rows cut)
            area_rel = ((8 + 1 - db) ** 2) / ((8 + 1) ** 2)
            rows.append({"size": f"{m}x{k}x{n}", "drop_bits": db,
                         "mean_rel_err": float(err),
                         "squarer_area_vs_exact": area_rel})
    return rows


def approx_float_error():
    """bf16-squarer float path error vs f32 exact."""
    rng = np.random.default_rng(1)
    rows = []
    for (m, k, n) in ((64, 64, 64), (128, 256, 64)):
        a = rng.normal(size=(m, k)).astype(np.float32)
        b = rng.normal(size=(k, n)).astype(np.float32)
        exact = a @ b
        out = np.asarray(M.pm_matmul_approx(jnp.asarray(a), jnp.asarray(b)))
        rel = np.abs(out - exact).max() / (np.abs(exact).max() + 1e-9)
        rows.append({"size": f"{m}x{k}x{n}", "squarer": "bf16",
                     "max_rel_err": float(rel)})
    return rows
