"""Paper's quantitative claims: measured square-per-multiply ratios.

The paper has no experimental tables; its results are the closed-form ratios
eq (6), (20), (36).  We EXECUTE the square-based algorithms on the
instrumented counting backend and report measured ratios next to the paper's
formulas -- reproduction means measured == formula and ratio -> {1, 4, 3}.
"""
from __future__ import annotations

import numpy as np

from repro.core import counting as CT

SIZES = [(4, 4, 4), (16, 16, 16), (64, 64, 64), (256, 256, 256),
         (1024, 512, 1024)]


def real_matmul_ratio():
    """Paper eq (6): (MNP + MN + NP) / MNP -> 1."""
    rows = []
    for m, k, n in SIZES:
        if m * k * n > 64 ** 3:         # count analytically above exec scale
            measured = CT.real_matmul_square_count(m, k, n)
        else:
            ctr = CT.OpCounter()
            a = np.random.default_rng(0).normal(size=(m, k))
            b = np.random.default_rng(1).normal(size=(k, n))
            out = CT.pm_matmul_counted(a, b, ctr)
            assert np.allclose(out, a @ b), "square-form result mismatch"
            assert ctr.mults == 0
            measured = ctr.squares
        formula = CT.real_matmul_square_count(m, k, n)
        paper = 1 + 1 / n + 1 / m
        rows.append({"M": m, "N": k, "P": n, "squares_measured": measured,
                     "squares_formula": formula,
                     "ratio": measured / (m * k * n), "paper_ratio": paper,
                     "exact_match": measured == formula})
    return rows


def cpm4_ratio():
    """Paper eq (20): (4MNP + 2MN + 2NP) / MNP -> 4."""
    rows = []
    for m, k, n in SIZES:
        if m * k * n > 32 ** 3:
            measured = CT.cpm4_square_count(m, k, n)
        else:
            ctr = CT.OpCounter()
            rng = np.random.default_rng(2)
            x = rng.normal(size=(m, k)) + 1j * rng.normal(size=(m, k))
            y = rng.normal(size=(k, n)) + 1j * rng.normal(size=(k, n))
            out = CT.cpm4_matmul_counted(x, y, ctr)
            assert np.allclose(out, x @ y)
            measured = ctr.squares
        formula = CT.cpm4_square_count(m, k, n)
        rows.append({"M": m, "N": k, "P": n, "squares_measured": measured,
                     "ratio": measured / (m * k * n),
                     "paper_ratio": 4 + 2 / n + 2 / m,
                     "exact_match": measured == formula})
    return rows


def cpm3_ratio():
    """Paper eq (36): (3MNP + 3MN + 3NP) / MNP -> 3."""
    rows = []
    for m, k, n in SIZES:
        if m * k * n > 32 ** 3:
            measured = CT.cpm3_square_count(m, k, n)
        else:
            ctr = CT.OpCounter()
            rng = np.random.default_rng(3)
            x = rng.normal(size=(m, k)) + 1j * rng.normal(size=(m, k))
            y = rng.normal(size=(k, n)) + 1j * rng.normal(size=(k, n))
            out = CT.cpm3_matmul_counted(x, y, ctr)
            assert np.allclose(out, x @ y)
            measured = ctr.squares
        formula = CT.cpm3_square_count(m, k, n)
        rows.append({"M": m, "N": k, "P": n, "squares_measured": measured,
                     "ratio": measured / (m * k * n),
                     "paper_ratio": 3 + 3 / n + 3 / m,
                     "exact_match": measured == formula})
    return rows
