"""Serving-engine benchmark: utilization-shaped, not single-call-shaped.

Runs the paged continuous-batching engine (`repro.serve.engine`) over a
ragged request mix on a small-but-real LM and reports the serving metrics
the ROADMAP north star cares about: tokens/s, time-to-first-token,
cache-block utilization, batch occupancy -- and the paper's quantity, the
fraction of serving contraction FLOPs routed through square-form
arithmetic (`core/counting`).

Five engine configurations ride one workload:

- ``standard``        -- multiplier-baseline GEMMs (context row);
- ``square_raw``      -- ``square_pallas`` GEMMs, weights prepared per
                         call (the per-call column prep is real work);
- ``square_prepared`` -- the same square route with ``LM.prepare_params``
                         run ONCE at engine start (paper §4-§5: the
                         weight-stationary regime decode serving lives in);
- ``square_guarded``  -- square_prepared plus the full resilience layer
                         (``EngineConfig(guard=True)``: per-step logits
                         finiteness checks AND the core-layer square-route
                         guard, live because the bench is eager).  Its
                         gated ratio vs square_prepared is the measured
                         cost of the guard-rails on the happy path.
- ``square_traced``   -- square_prepared with structured tracing
                         (``repro.obs.trace``) live for the whole run.
                         Its gated ratio vs square_prepared (>= 0.9 -
                         tol) bounds the cost of full observability; the
                         prepared row itself runs with tracing disabled,
                         so its own gates double as the
                         tracing-off-is-free check.

Execution is EAGER (``EngineConfig(jit=False)``: the engine steps run
op-by-op, like the prepared-operand rows in ``kernel_timing.py``): under
jit both paths trace identically and the prep is free via jit caching;
eager/interpret execution is where the amortization contract is
measurable.  The square_raw / square_prepared runs are INTERLEAVED across
reps so their ratio is immune to runner-load drift (same rationale as
``kernel_timing._time_pair``).

A second, JITTED row family (:func:`long_context_rows`) covers the
regime the eager rows cannot reach: ~512-token prefills decoding against
long block tables, where the paged-attention read itself is the
interesting cost.  One workload runs under both read routes (the fused
square kernel vs the dense gather; `REPRO_ROUTE=paged_attn=...` pinned
at trace time) on pre-warmed engines, so the gated ratio is
steady-state serving throughput with trace/compile excluded -- plus an
SWA pair (window eviction on/off) whose gated quantity is the
deterministic ``peak_blocks_used`` footprint.

``BENCH_serving.json`` rows feed the ``run.py --check`` regression gate:
the prepared-square row must stay >= 1.0x the raw-square row (minus
``$BENCH_CHECK_TOL``), the kernel-route row >= 1.0x - tol the gather
row with identical greedy tokens, and the evicting SWA engine strictly
below the retaining one on ``peak_blocks_used``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
from typing import Dict, List

import jax

from repro.configs.base import ContractionPolicy, ModelConfig
from repro.core import counting
from repro.launch.serve import make_requests
from repro.models.lm import build_model
from repro.obs import trace as obs_trace
from repro.serve.engine import Engine, EngineConfig, EngineMetrics
from repro.serve.server import Request

SERVING_JSON = "BENCH_serving.json"

# Serving-bench model: small enough for eager interpret execution, real
# enough that decode hits the engine's characteristic GEMM shapes
# (qkv/out 256x256, ffn 256<->1024, vocab logits 4096) at slot-batch M.
# scan_layers=False so LM.prepare_params covers the WHOLE stack.
BENCH_POLICY = ContractionPolicy.of(attn_scores="standard",
                                    attn_pv="standard")
BENCH_CFG = ModelConfig(
    name="serve-bench", family="dense", n_layers=2, d_model=256,
    n_heads=4, n_kv_heads=4, d_ff=1024, vocab=4096, head_dim=64,
    dtype="float32", scan_layers=False, remat="none", attn_chunk_q=16,
    attn_chunk_kv=16, loss_chunk=16, max_seq=128,
    matmul_mode="square_pallas", contraction_policy=BENCH_POLICY)

ENGINE_KW = dict(max_slots=8, block_size=8, num_blocks=64, blocks_per_seq=6,
                 prefill_chunk=16, max_new_tokens=4)
N_REQUESTS = 8

# Long-context paged-decode geometry: the regime the fused paged-attention
# kernel exists for -- ~512-token prefills whose block tables are long
# enough (T = blocks_per_seq * block_size = 640 >= PAGED_KERNEL_MIN_T)
# that the gather route's per-step (B, T, KV, hd) copy is real traffic.
# KV=1 (MQA-shaped) keeps the kernel grid small under interpret mode
# while the gathered window stays full-size.  These rows run JITTED
# (unlike the eager rows above): the kernel-vs-gather contest is a
# steady-state serving contest, so each engine is warmed once (paying
# trace+compile) and then timed over fresh requests on the same jit
# closures -- route pinned via REPRO_ROUTE at trace time.
LONG_CFG = dataclasses.replace(BENCH_CFG, name="serve-bench-long",
                               n_kv_heads=1, max_seq=1024)
# the SWA variant: same geometry with every layer windowed, so the
# engine's block-level eviction (EngineConfig.window_eviction) can
# retire aged blocks; window == block_size keeps the live footprint at
# ceil(window/bs) + 1 = 2 blocks/seq no matter how long decode runs
SWA_CFG = dataclasses.replace(LONG_CFG, name="serve-bench-swa", window=64)
LONG_ENGINE_KW = dict(max_slots=2, block_size=64, num_blocks=24,
                      blocks_per_seq=10, prefill_chunk=128,
                      max_new_tokens=32)
N_LONG = 2
LONG_LO, LONG_HI = 512, 521

# Tolerance floor for the kernel-vs-gather tokens/s gate.  The fused
# kernel's no-copy dataflow pays off on the TPU "mkn" schedule; on this
# CPU/interpret proxy host the per-grid-step op overhead keeps the
# attention call itself behind the gather copy (same story as the
# fused-vs-im2col conv near-parity -- see docs/tuning.md), so the
# engine-level ratio sits a little under 1.0 (~0.8 measured).  The gate
# still catches a route that goes catastrophically slow or diverges; on
# TPU hosts tighten $BENCH_CHECK_TOL and re-measure.
LONG_ROW_TOL_FLOOR = 0.25


@contextlib.contextmanager
def _pinned_paged_route(route: str):
    """Pin the paged-attention route for everything traced inside."""
    prev = os.environ.get("REPRO_ROUTE")
    os.environ["REPRO_ROUTE"] = f"paged_attn={route}"
    try:
        yield
    finally:
        if prev is None:
            os.environ.pop("REPRO_ROUTE", None)
        else:
            os.environ["REPRO_ROUTE"] = prev


def _run_once(model, params, *, prepared: bool, guard: bool = False,
              traced: bool = False) -> Engine:
    eng = Engine(model, params, EngineConfig(prepared=prepared, jit=False,
                                             guard=guard, **ENGINE_KW))
    reqs = make_requests(model.cfg, N_REQUESTS, seed=17, lo=4, hi=13)
    if traced:
        # full structured tracing live for the whole run (the overhead
        # row): every tick/prefill/decode span lands in the ring buffer
        with obs_trace.capture() as tr:
            eng.run(reqs)
        eng.trace_records = len(tr.records())
    else:
        eng.run(reqs)
    return eng


def _row(name: str, mode: str, eng: Engine, cfg: ModelConfig = BENCH_CFG,
         kw: Dict = ENGINE_KW, **extra) -> Dict:
    m = eng.metrics
    row = {"name": name, "mode": mode,
           "shape": f"L{cfg.n_layers} d{cfg.d_model} "
                    f"v{cfg.padded_vocab} slots{kw['max_slots']}",
           "tokens_per_s": m.tokens_per_s,
           "tokens_out": m.tokens_out,
           "mean_ttft_s": m.mean_ttft_s,
           "mean_block_utilization": m.mean_utilization,
           "peak_blocks_used": m.peak_blocks_used,
           "batch_occupancy": m.batch_occupancy,
           "preemptions": m.preemptions}
    row.update(extra)
    return row


def serving_rows(reps: int = 2) -> List[Dict]:
    """Measure the three engine configurations; returns BENCH rows."""
    model_sq = build_model(BENCH_CFG)
    params = model_sq.init(jax.random.PRNGKey(0))
    cfg_std = dataclasses.replace(BENCH_CFG, matmul_mode="standard",
                                  contraction_policy=None)
    model_std = build_model(cfg_std)

    # square-routed fraction of serving FLOPs, counted on an eager run
    # (trace-time counting records nothing under cached jit); this run
    # doubles as the raw-config warmup
    with counting.track_contractions() as ctr:
        eng_counted = _run_once(model_sq, params, prepared=False)
    fraction_square = ctr.fraction_square
    audit = ctr.summary()
    # cross-validate the observability layer against the audit: publish
    # the audit into the counted engine's registry and read the gauge
    # back out of the snapshot -- run.py --check gates the two agreeing
    snap = eng_counted.obs_snapshot(audit=audit)
    registry_fraction_square = snap["gauges"]["counting_fraction_square"]
    c = snap["counters"]
    registry_conserved = (
        sum(c[f"engine_requests_{k}_total"]
            for k in ("completed", "rejected", "shed", "timeouts",
                      "failures", "cancelled"))
        == c["engine_requests_submitted_total"])

    # one warmup per remaining config: the first run of each pays one-time
    # costs (plan-cache fills, tuning-cache consults, allocator warmup)
    # that would otherwise bias whichever config runs first
    _run_once(model_sq, params, prepared=True)
    _run_once(model_sq, params, prepared=True, guard=True)
    _run_once(model_sq, params, prepared=True, traced=True)
    _run_once(model_std, params, prepared=False)

    best: Dict[str, Engine] = {}
    for _ in range(reps):
        # interleave raw/prepared/guarded/traced so the gated ratios are
        # immune to progressive runner throttling across the bench
        for key, model, prep, grd, trc in (
                ("raw", model_sq, False, False, False),
                ("prepared", model_sq, True, False, False),
                ("guarded", model_sq, True, True, False),
                ("traced", model_sq, True, False, True),
                ("standard", model_std, False, False, False)):
            eng = _run_once(model, params, prepared=prep, guard=grd,
                            traced=trc)
            if key not in best or (eng.metrics.tokens_per_s
                                   > best[key].metrics.tokens_per_s):
                best[key] = eng

    tps_raw = best["raw"].metrics.tokens_per_s
    tps_prep = best["prepared"].metrics.tokens_per_s
    tps_grd = best["guarded"].metrics.tokens_per_s
    tps_trc = best["traced"].metrics.tokens_per_s
    return [
        _row("serving_engine_standard[interp-eager]", "standard",
             best["standard"]),
        _row("serving_engine_square_raw[interp-eager]",
             "square_pallas/per-call-prep", best["raw"],
             fraction_square=fraction_square,
             registry_fraction_square=registry_fraction_square,
             registry_conserved=registry_conserved),
        _row("serving_engine_square_prepared[interp-eager]",
             "square_pallas/prepared", best["prepared"],
             fraction_square=fraction_square,
             speedup_vs_raw=tps_prep / tps_raw if tps_raw else 0.0),
        _row("serving_engine_square_guarded[interp-eager]",
             "square_pallas/prepared+guard", best["guarded"],
             guard_trips=best["guarded"].metrics.guard_trips,
             speedup_vs_prepared=tps_grd / tps_prep if tps_prep else 0.0),
        _row("serving_engine_square_traced[interp-eager]",
             "square_pallas/prepared+trace", best["traced"],
             trace_records=getattr(best["traced"], "trace_records", 0),
             speedup_vs_prepared=tps_trc / tps_prep if tps_prep else 0.0),
    ]


def _long_requests(rid0: int) -> List[Request]:
    """The long-context workload, re-submittable with fresh rids (results
    are keyed by rid, so a reused engine needs distinct ids per run)."""
    return [Request(rid0 + r.rid, r.tokens)
            for r in make_requests(LONG_CFG, N_LONG, seed=29,
                                   lo=LONG_LO, hi=LONG_HI)]


def long_context_rows(reps: int = 3) -> List[Dict]:
    """Long-context paged-decode rows (jitted): the fused paged-attention
    kernel vs the dense gather route on one workload, plus the SWA
    windowed-eviction footprint pair.  Greedy tokens must agree between
    the routes and between eviction on/off -- recorded per row
    (``tokens_match_*``) and gated by :func:`check_serving`."""
    model = build_model(LONG_CFG)
    params = model.init(jax.random.PRNGKey(1))
    nxt = [0]

    # the "kernel" engine runs under ``paged_attn=auto``: the planner's
    # own cost rule sends decode steps (S=1, T=640) to the kernel and
    # prefill chunks (S=128) to gather -- the production dispatch, not a
    # blanket pin.  The baseline engine pins ``gather`` outright.
    ROUTE_ENV = {"kernel": "auto", "gather": "gather"}

    def _run(eng: Engine, route: str, measured: bool) -> List[List[int]]:
        rid0, nxt[0] = nxt[0], nxt[0] + N_LONG
        if measured:
            eng.metrics = EngineMetrics()     # drop warmup trace+compile
        with _pinned_paged_route(ROUTE_ENV.get(route, route)):
            res = eng.run(_long_requests(rid0))
        assert all(res[rid0 + i].ok for i in range(N_LONG))
        return [list(res[rid0 + i].tokens) for i in range(N_LONG)]

    engines: Dict[str, Engine] = {}
    for route in ("gather", "kernel"):
        engines[route] = Engine(model, params,
                                EngineConfig(prepared=True, jit=True,
                                             **LONG_ENGINE_KW))
        _run(engines[route], route, measured=False)     # warmup: compile
    best: Dict[str, Dict] = {}
    tokens: Dict[str, List] = {}
    for _ in range(reps):
        # interleaved like the eager rows: the gated ratio is same-process
        for route in ("gather", "kernel"):
            tokens[route] = _run(engines[route], route, measured=True)
            m = engines[route].metrics
            if route not in best \
                    or m.tokens_per_s > best[route]["tokens_per_s"]:
                best[route] = _row(
                    f"serving_engine_long_{route}[jit]",
                    f"square_pallas/paged-{route}", engines[route],
                    cfg=LONG_CFG, kw=LONG_ENGINE_KW)
    tps_g = best["gather"]["tokens_per_s"]
    best["kernel"]["speedup_vs_gather"] = \
        best["kernel"]["tokens_per_s"] / tps_g if tps_g else 0.0
    best["kernel"]["tokens_match_gather"] = \
        tokens["kernel"] == tokens["gather"]

    # SWA eviction pair: peak_blocks_used is allocator bookkeeping, fully
    # deterministic -- one run per side suffices.  The kernel route rides
    # along so the window mask path gets jitted bench coverage too.
    model_swa = build_model(SWA_CFG)
    params_swa = model_swa.init(jax.random.PRNGKey(1))
    swa_rows, swa_tokens = {}, {}
    for evict in (False, True):
        eng = Engine(model_swa, params_swa,
                     EngineConfig(prepared=True, jit=True,
                                  window_eviction=evict, **LONG_ENGINE_KW))
        key = "evict" if evict else "retain"
        swa_tokens[key] = _run(eng, "kernel", measured=False)
        swa_rows[key] = _row(f"serving_engine_swa_{key}[jit]",
                             f"square_pallas/window-{key}", eng,
                             cfg=SWA_CFG, kw=LONG_ENGINE_KW)
    swa_rows["evict"]["blocks_vs_retain"] = (
        swa_rows["evict"]["peak_blocks_used"]
        / swa_rows["retain"]["peak_blocks_used"]
        if swa_rows["retain"]["peak_blocks_used"] else 1.0)
    swa_rows["evict"]["tokens_match_retain"] = \
        swa_tokens["evict"] == swa_tokens["retain"]
    return [best["gather"], best["kernel"],
            swa_rows["retain"], swa_rows["evict"]]


def build_serving_payload(rows: List[Dict]) -> Dict:
    return {"rows": rows}


def write_serving_json(payload: Dict, path: str = SERVING_JSON) -> Dict:
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"\nwrote {path}")
    return payload


def check_serving(payload: Dict, tol: float) -> List[str]:
    """Regression gate over the serving rows (called by run.py --check):

    - the prepared-square engine must not serve slower than the raw-square
      engine (``speedup_vs_raw >= 1.0 - tol`` -- the acceptance bar for
      the weight-stationary serving contract);
    - the square engine must keep its contraction FLOPs square-routed
      (``fraction_square >= 0.9``: a dispatch regression that silently
      reroutes serving GEMMs to the multiplier baseline fails here);
    - the guard-rails must stay cheap on the happy path: the guarded
      engine's tokens/s must hold ``speedup_vs_prepared >= 1.0 - tol``
      against the unguarded prepared engine, with zero guard trips on a
      healthy workload;
    - the fused paged-attention kernel must hold its route on the
      long-context rows: ``speedup_vs_gather >= 1.0 - tol`` (tol floored
      at :data:`LONG_ROW_TOL_FLOOR` -- the interpret-host slack, see the
      constant's comment) in steady-state serving, with greedy tokens
      identical to the gather route (``tokens_match_gather``);
    - SWA windowed eviction must actually cap the footprint:
      the evicting engine's ``peak_blocks_used`` strictly below the
      retain-everything engine's, with identical greedy tokens
      (``tokens_match_retain``);
    - the observability layer must agree with the ground truth: the
      registry's ``counting_fraction_square`` gauge must equal the
      counting audit's fraction, and the registry's terminal request
      counters must partition submissions (``registry_conserved``);
    - tracing must stay cheap: the fully-traced engine's tokens/s must
      hold ``speedup_vs_prepared >= 0.9 - tol``, with at least one span
      actually recorded (``trace_records > 0``).
    """
    failures = []
    rows = {r["name"]: r for r in payload.get("rows", [])}
    prep = rows.get("serving_engine_square_prepared[interp-eager]")
    if prep is None:
        failures.append("serving: prepared-square row missing")
    else:
        ratio = prep.get("speedup_vs_raw", 0.0)
        if ratio < 1.0 - tol:
            failures.append(f"serving: prepared-square tokens/s ratio "
                            f"{ratio:.2f} < {1.0 - tol:.2f} vs raw-square")
        if prep.get("fraction_square", 0.0) < 0.9:
            failures.append(
                f"serving: fraction_square "
                f"{prep.get('fraction_square', 0.0):.2f} < 0.90")
    grd = rows.get("serving_engine_square_guarded[interp-eager]")
    if grd is None:
        failures.append("serving: guarded-square row missing")
    else:
        ratio = grd.get("speedup_vs_prepared", 0.0)
        if ratio < 1.0 - tol:
            failures.append(f"serving: guarded tokens/s ratio {ratio:.2f} "
                            f"< {1.0 - tol:.2f} vs prepared (resilience "
                            f"overhead regression)")
        if grd.get("guard_trips", 0) != 0:
            failures.append(f"serving: {grd['guard_trips']} guard trips "
                            f"on the healthy bench workload")
    raw = rows.get("serving_engine_square_raw[interp-eager]")
    if raw is not None and "registry_fraction_square" in raw:
        if abs(raw["registry_fraction_square"]
               - raw.get("fraction_square", 0.0)) > 1e-9:
            failures.append(
                f"serving: registry fraction_square gauge "
                f"({raw['registry_fraction_square']:.4f}) disagrees with "
                f"the counting audit ({raw.get('fraction_square', 0.0):.4f})")
        if not raw.get("registry_conserved", False):
            failures.append("serving: registry terminal counters do not "
                            "partition submitted requests")
    trc = rows.get("serving_engine_square_traced[interp-eager]")
    if trc is None:
        failures.append("serving: traced-engine row missing")
    else:
        ratio = trc.get("speedup_vs_prepared", 0.0)
        if ratio < 0.9 - tol:
            failures.append(f"serving: traced-engine tokens/s ratio "
                            f"{ratio:.2f} < {0.9 - tol:.2f} vs prepared "
                            f"(tracing overhead regression)")
        if trc.get("trace_records", 0) <= 0:
            failures.append("serving: traced-engine row recorded no spans")
    krn = rows.get("serving_engine_long_kernel[jit]")
    if krn is None:
        failures.append("serving: long-context kernel row missing")
    else:
        ltol = max(tol, LONG_ROW_TOL_FLOOR)
        ratio = krn.get("speedup_vs_gather", 0.0)
        if ratio < 1.0 - ltol:
            failures.append(f"serving: paged-attn kernel tokens/s ratio "
                            f"{ratio:.2f} < {1.0 - ltol:.2f} vs gather on "
                            f"the long-context rows")
        if not krn.get("tokens_match_gather", False):
            failures.append("serving: kernel-route greedy tokens diverge "
                            "from the gather route")
    evict = rows.get("serving_engine_swa_evict[jit]")
    retain = rows.get("serving_engine_swa_retain[jit]")
    if evict is None or retain is None:
        failures.append("serving: SWA eviction row pair missing")
    else:
        if evict["peak_blocks_used"] >= retain["peak_blocks_used"]:
            failures.append(
                f"serving: windowed eviction did not reduce "
                f"peak_blocks_used ({evict['peak_blocks_used']} vs "
                f"{retain['peak_blocks_used']} retained)")
        if not evict.get("tokens_match_retain", False):
            failures.append("serving: SWA eviction changed greedy tokens")
    return failures


if __name__ == "__main__":
    rows = serving_rows() + long_context_rows()
    for r in rows:
        print(r)
    write_serving_json(build_serving_payload(rows))
