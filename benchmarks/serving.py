"""Serving-engine benchmark: utilization-shaped, not single-call-shaped.

Runs the paged continuous-batching engine (`repro.serve.engine`) over a
ragged request mix on a small-but-real LM and reports the serving metrics
the ROADMAP north star cares about: tokens/s, time-to-first-token,
cache-block utilization, batch occupancy -- and the paper's quantity, the
fraction of serving contraction FLOPs routed through square-form
arithmetic (`core/counting`).

Four engine configurations ride one workload:

- ``standard``        -- multiplier-baseline GEMMs (context row);
- ``square_raw``      -- ``square_pallas`` GEMMs, weights prepared per
                         call (the per-call column prep is real work);
- ``square_prepared`` -- the same square route with ``LM.prepare_params``
                         run ONCE at engine start (paper §4-§5: the
                         weight-stationary regime decode serving lives in);
- ``square_guarded``  -- square_prepared plus the full resilience layer
                         (``EngineConfig(guard=True)``: per-step logits
                         finiteness checks AND the core-layer square-route
                         guard, live because the bench is eager).  Its
                         gated ratio vs square_prepared is the measured
                         cost of the guard-rails on the happy path.

Execution is EAGER (``EngineConfig(jit=False)``: the engine steps run
op-by-op, like the prepared-operand rows in ``kernel_timing.py``): under
jit both paths trace identically and the prep is free via jit caching;
eager/interpret execution is where the amortization contract is
measurable.  The square_raw / square_prepared runs are INTERLEAVED across
reps so their ratio is immune to runner-load drift (same rationale as
``kernel_timing._time_pair``).

``BENCH_serving.json`` rows feed the ``run.py --check`` regression gate:
the prepared-square row must stay >= 1.0x the raw-square row (minus
``$BENCH_CHECK_TOL``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List

import jax

from repro.configs.base import ContractionPolicy, ModelConfig
from repro.core import counting
from repro.launch.serve import make_requests
from repro.models.lm import build_model
from repro.serve.engine import Engine, EngineConfig

SERVING_JSON = "BENCH_serving.json"

# Serving-bench model: small enough for eager interpret execution, real
# enough that decode hits the engine's characteristic GEMM shapes
# (qkv/out 256x256, ffn 256<->1024, vocab logits 4096) at slot-batch M.
# scan_layers=False so LM.prepare_params covers the WHOLE stack.
BENCH_POLICY = ContractionPolicy.of(attn_scores="standard",
                                    attn_pv="standard")
BENCH_CFG = ModelConfig(
    name="serve-bench", family="dense", n_layers=2, d_model=256,
    n_heads=4, n_kv_heads=4, d_ff=1024, vocab=4096, head_dim=64,
    dtype="float32", scan_layers=False, remat="none", attn_chunk_q=16,
    attn_chunk_kv=16, loss_chunk=16, max_seq=128,
    matmul_mode="square_pallas", contraction_policy=BENCH_POLICY)

ENGINE_KW = dict(max_slots=8, block_size=8, num_blocks=64, blocks_per_seq=6,
                 prefill_chunk=16, max_new_tokens=4)
N_REQUESTS = 8


def _run_once(model, params, *, prepared: bool, guard: bool = False) -> Engine:
    eng = Engine(model, params, EngineConfig(prepared=prepared, jit=False,
                                             guard=guard, **ENGINE_KW))
    eng.run(make_requests(model.cfg, N_REQUESTS, seed=17, lo=4, hi=13))
    return eng


def _row(name: str, mode: str, eng: Engine, **extra) -> Dict:
    m = eng.metrics
    row = {"name": name, "mode": mode,
           "shape": f"L{BENCH_CFG.n_layers} d{BENCH_CFG.d_model} "
                    f"v{BENCH_CFG.padded_vocab} slots{ENGINE_KW['max_slots']}",
           "tokens_per_s": m.tokens_per_s,
           "tokens_out": m.tokens_out,
           "mean_ttft_s": m.mean_ttft_s,
           "mean_block_utilization": m.mean_utilization,
           "peak_blocks_used": m.peak_blocks_used,
           "batch_occupancy": m.batch_occupancy,
           "preemptions": m.preemptions}
    row.update(extra)
    return row


def serving_rows(reps: int = 2) -> List[Dict]:
    """Measure the three engine configurations; returns BENCH rows."""
    model_sq = build_model(BENCH_CFG)
    params = model_sq.init(jax.random.PRNGKey(0))
    cfg_std = dataclasses.replace(BENCH_CFG, matmul_mode="standard",
                                  contraction_policy=None)
    model_std = build_model(cfg_std)

    # square-routed fraction of serving FLOPs, counted on an eager run
    # (trace-time counting records nothing under cached jit); this run
    # doubles as the raw-config warmup
    with counting.track_contractions() as ctr:
        _run_once(model_sq, params, prepared=False)
    fraction_square = ctr.fraction_square

    # one warmup per remaining config: the first run of each pays one-time
    # costs (plan-cache fills, tuning-cache consults, allocator warmup)
    # that would otherwise bias whichever config runs first
    _run_once(model_sq, params, prepared=True)
    _run_once(model_sq, params, prepared=True, guard=True)
    _run_once(model_std, params, prepared=False)

    best: Dict[str, Engine] = {}
    for _ in range(reps):
        # interleave raw/prepared/guarded so the gated ratios are immune
        # to progressive runner throttling across the bench
        for key, model, prep, grd in (("raw", model_sq, False, False),
                                      ("prepared", model_sq, True, False),
                                      ("guarded", model_sq, True, True),
                                      ("standard", model_std, False, False)):
            eng = _run_once(model, params, prepared=prep, guard=grd)
            if key not in best or (eng.metrics.tokens_per_s
                                   > best[key].metrics.tokens_per_s):
                best[key] = eng

    tps_raw = best["raw"].metrics.tokens_per_s
    tps_prep = best["prepared"].metrics.tokens_per_s
    tps_grd = best["guarded"].metrics.tokens_per_s
    return [
        _row("serving_engine_standard[interp-eager]", "standard",
             best["standard"]),
        _row("serving_engine_square_raw[interp-eager]",
             "square_pallas/per-call-prep", best["raw"],
             fraction_square=fraction_square),
        _row("serving_engine_square_prepared[interp-eager]",
             "square_pallas/prepared", best["prepared"],
             fraction_square=fraction_square,
             speedup_vs_raw=tps_prep / tps_raw if tps_raw else 0.0),
        _row("serving_engine_square_guarded[interp-eager]",
             "square_pallas/prepared+guard", best["guarded"],
             guard_trips=best["guarded"].metrics.guard_trips,
             speedup_vs_prepared=tps_grd / tps_prep if tps_prep else 0.0),
    ]


def build_serving_payload(rows: List[Dict]) -> Dict:
    return {"rows": rows}


def write_serving_json(payload: Dict, path: str = SERVING_JSON) -> Dict:
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"\nwrote {path}")
    return payload


def check_serving(payload: Dict, tol: float) -> List[str]:
    """Regression gate over the serving rows (called by run.py --check):

    - the prepared-square engine must not serve slower than the raw-square
      engine (``speedup_vs_raw >= 1.0 - tol`` -- the acceptance bar for
      the weight-stationary serving contract);
    - the square engine must keep its contraction FLOPs square-routed
      (``fraction_square >= 0.9``: a dispatch regression that silently
      reroutes serving GEMMs to the multiplier baseline fails here);
    - the guard-rails must stay cheap on the happy path: the guarded
      engine's tokens/s must hold ``speedup_vs_prepared >= 1.0 - tol``
      against the unguarded prepared engine, with zero guard trips on a
      healthy workload.
    """
    failures = []
    rows = {r["name"]: r for r in payload.get("rows", [])}
    prep = rows.get("serving_engine_square_prepared[interp-eager]")
    if prep is None:
        failures.append("serving: prepared-square row missing")
    else:
        ratio = prep.get("speedup_vs_raw", 0.0)
        if ratio < 1.0 - tol:
            failures.append(f"serving: prepared-square tokens/s ratio "
                            f"{ratio:.2f} < {1.0 - tol:.2f} vs raw-square")
        if prep.get("fraction_square", 0.0) < 0.9:
            failures.append(
                f"serving: fraction_square "
                f"{prep.get('fraction_square', 0.0):.2f} < 0.90")
    grd = rows.get("serving_engine_square_guarded[interp-eager]")
    if grd is None:
        failures.append("serving: guarded-square row missing")
    else:
        ratio = grd.get("speedup_vs_prepared", 0.0)
        if ratio < 1.0 - tol:
            failures.append(f"serving: guarded tokens/s ratio {ratio:.2f} "
                            f"< {1.0 - tol:.2f} vs prepared (resilience "
                            f"overhead regression)")
        if grd.get("guard_trips", 0) != 0:
            failures.append(f"serving: {grd['guard_trips']} guard trips "
                            f"on the healthy bench workload")
    return failures


if __name__ == "__main__":
    rows = serving_rows()
    for r in rows:
        print(r)
    write_serving_json(build_serving_payload(rows))
