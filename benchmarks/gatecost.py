"""Paper's headline hardware claim: area savings of square-based designs.

Reproduces the gate-count argument (squarer ~ half a multiplier, paper ref
[1]) through the analytical cost model: PM-MAC vs MAC, CPM4/CPM3 vs 3-mult
complex MAC, square systolic arrays (Fig.2) and tensor cores (Fig.4/5).
"""
from __future__ import annotations

from repro.core import cost_model as cm


def mac_savings():
    return cm.savings_table(bitwidths=(8, 16, 32))


def systolic_sweep():
    rows = []
    for size in (32, 128, 256):
        for bits in (8, 16):
            sq = cm.systolic_array_cost(size, size, bits, True)
            mac = cm.systolic_array_cost(size, size, bits, False)
            rows.append({"array": f"{size}x{size}", "bits": bits,
                         "sq_area": sq.area, "mac_area": mac.area,
                         "ratio": sq.ratio_to(mac)})
    return rows


def tensor_core_sweep():
    rows = []
    for (m, n, k) in ((4, 4, 4), (8, 8, 8), (16, 16, 16)):
        for bits in (8, 16):
            sq = cm.tensor_core_cost(m, n, k, bits, True)
            mac = cm.tensor_core_cost(m, n, k, bits, False)
            rows.append({"core": f"{m}x{n}x{k}", "bits": bits,
                         "ratio": sq.ratio_to(mac)})
    return rows
