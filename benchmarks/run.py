"""Benchmark harness: one function per paper claim/table.

Prints ``name,us_per_call,shape,mode`` CSV rows (timing benches) and claim
tables (op-count ratios, gate-cost model).  Roofline benches read the
dry-run JSON if present.

``--json`` additionally writes ``BENCH_kernels.json``: the machine-readable
perf trajectory (current kernel timings alongside the frozen seed-commit
baselines, with speedup ratios) that future PRs use to track kernel
speedups against this baseline.
"""
from __future__ import annotations

import json
import os
import sys

# Frozen interpret-mode timings of the rank-1 seed kernels: the
# denominators for the speedup column in BENCH_kernels.json.  Do not
# update them when kernels get faster; they are the baseline.
#
# Derivation: measured with kernel_timing._time's min-of-15 statistic on
# seed-EQUIVALENT plans (kc=1 "mkn" matmuls, tb=1 conv -- the chunked
# kernels degenerate to exactly the seed dataflow there, and
# tests/test_kernel_tuning.py proves the equivalence), so numerator and
# denominator use the same statistic.  The seed commit (ae5dab9) itself
# timed mean-of-5: 1423.8 / 1096.2 / 115.2 us respectively -- consistent
# with these, but not statistic-compatible with the current harness.
SEED_BASELINE = [
    {"name": "pallas_sq_matmul[interp]", "us_per_call": 1515.0,
     "shape": "128x128x128", "mode": "f32"},
    {"name": "pallas_cpm3_matmul[interp]", "us_per_call": 1011.8,
     "shape": "64x64x64", "mode": "c64"},
    {"name": "pallas_sq_conv[interp]", "us_per_call": 84.9,
     "shape": "L=2048 taps=16", "mode": "f32"},
]


def _print_rows(title, rows):
    print(f"\n# {title}")
    if not rows:
        print("(no rows)")
        return
    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(f"{r[k]:.6g}" if isinstance(r[k], float) else str(r[k])
                       for k in keys))


def write_bench_json(timing_rows, path="BENCH_kernels.json"):
    """Write the perf-trajectory JSON: current rows + seed baseline +
    per-kernel speedup (seed_us / current_us) where names match."""
    seed_by_name = {r["name"]: r for r in SEED_BASELINE}
    by_name = {r["name"]: r for r in timing_rows}
    rank1 = by_name.get("pallas_sq_matmul_rank1[interp]")
    # im2col conv2d rows indexed by shape: every fused conv2d row gets its
    # same-shape, same-process (load-drift-immune) fused-vs-im2col ratio
    im2col_by_shape = {r["shape"]: r for r in timing_rows
                       if r.get("mode") == "f32/im2col"}
    rows = []
    for r in timing_rows:
        row = dict(r)
        seed = seed_by_name.get(r["name"])
        if seed is not None:
            row["seed_us_per_call"] = seed["us_per_call"]
            row["speedup_vs_seed"] = seed["us_per_call"] / r["us_per_call"]
        if r["name"] == "pallas_sq_matmul[interp]" and rank1 is not None:
            # same-process rank-1 reference: load-drift-immune ratio
            row["speedup_vs_rank1"] = rank1["us_per_call"] / r["us_per_call"]
        im2col = im2col_by_shape.get(r["shape"])
        if r.get("mode") == "f32/fused" and im2col is not None:
            row["speedup_vs_im2col"] = \
                im2col["us_per_call"] / r["us_per_call"]
        rows.append(row)
    payload = {"seed_baseline": SEED_BASELINE, "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"\nwrote {path}")
    return payload


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    emit_json = "--json" in argv

    from benchmarks import gatecost, kernel_timing, ratios

    # Timing rows are measured FIRST, while the process is cold: the claim
    # tables below burn ~a minute of sustained compute, and on quota-
    # throttled runners (cgroup cpu-shares) that depresses any timing
    # measured afterwards by 1.5-2x.  Printed in their usual spot below.
    timing_rows = kernel_timing.matmul_modes() + kernel_timing.pallas_kernels()

    # --- Paper claim 1: real matmul, eq (6): ratio -> 1 ---
    rows = ratios.real_matmul_ratio()
    _print_rows("eq(6) real matmul squares/multiply (-> 1)", rows)
    assert all(r["exact_match"] for r in rows)

    # --- Paper claim 2: complex matmul with 4 squares, eq (20) ---
    rows = ratios.cpm4_ratio()
    _print_rows("eq(20) CPM4 squares/complex-multiply (-> 4)", rows)
    assert all(r["exact_match"] for r in rows)

    # --- Paper claim 3: complex matmul with 3 squares, eq (36) ---
    rows = ratios.cpm3_ratio()
    _print_rows("eq(36) CPM3 squares/complex-multiply (-> 3)", rows)
    assert all(r["exact_match"] for r in rows)

    # --- Paper claim 4: gate-count savings (squarer ~ multiplier/2) ---
    _print_rows("gate-cost model: MAC/CPM area ratios", gatecost.mac_savings())
    _print_rows("square systolic arrays (paper fig.2)", gatecost.systolic_sweep())
    _print_rows("square tensor cores (paper fig.4/5)", gatecost.tensor_core_sweep())

    # --- Paper conclusion: approximate squaring ---
    from benchmarks import approx
    _print_rows("approximate (truncated) squarers: int8 matmul error vs area",
                approx.approx_matmul_error())
    _print_rows("approximate (bf16) squarers: float matmul error",
                approx.approx_float_error())

    # --- timing microbenches (CSV contract: name,us_per_call,shape,mode) ---
    print("\n# timing (name,us_per_call,shape,mode)")
    for row in timing_rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['shape']},"
              f"{row['mode']}")

    if emit_json:
        write_bench_json(timing_rows)

    # --- roofline summary from the dry-run, if present ---
    for path in ("dryrun_single_pod.json", "dryrun_multi_pod.json"):
        if os.path.exists(path):
            from repro.roofline.report import build_report, format_table
            print(f"\n# roofline: {path}")
            print(format_table(build_report(path)))

    print("\nbenchmarks: ALL CLAIMS REPRODUCED")


if __name__ == "__main__":
    main()
