"""Benchmark harness: one function per paper claim/table.

Prints ``name,us_per_call,shape,mode`` CSV rows (timing benches) and claim
tables (op-count ratios, gate-cost model).  Roofline benches read the
dry-run JSON if present.

``--json`` additionally writes ``BENCH_kernels.json``: the machine-readable
perf trajectory (current kernel timings alongside the frozen seed-commit
baselines, with speedup ratios) that future PRs use to track kernel
speedups against this baseline.  The serving-engine smoke bench
(``benchmarks/serving.py``) rides along and writes ``BENCH_serving.json``
(tokens/s, TTFT, cache-block utilization, square-routed fraction), and the
training bench (``benchmarks/training.py``) writes ``BENCH_training.json``
(standard vs square-routed step time, square fraction of total train
FLOPs incl. the custom-VJP backward, fixed-seed loss bit-trajectory
hashes).

``--check`` is the CI bench regression gate: the fresh measurements are
compared against the seed baselines (every ``speedup_vs_seed`` must stay
>= 1.0, minus the ``$BENCH_CHECK_TOL`` runner-noise slack) and against the
*committed* ``BENCH_kernels.json`` (same-process ratio rows must not drop
>20%; route-choice rows must not flip).  Exits non-zero on violation --
this gate would have caught the PR 1 sq_conv 0.71x regression at commit
time.  Combined with ``--json``, the trajectory file is regenerated only
when the gate passes -- a failing run leaves the committed baseline
untouched so the gate cannot ratchet itself down.
"""
from __future__ import annotations

import json
import os
import sys

# Frozen interpret-mode timings of the rank-1 seed kernels: the
# denominators for the speedup column in BENCH_kernels.json.  Do not
# update them when kernels get faster; they are the baseline.
#
# Derivation: measured with kernel_timing._time's min-of-15 statistic on
# seed-EQUIVALENT plans (kc=1 "mkn" matmuls, tb=1 conv -- the chunked
# kernels degenerate to exactly the seed dataflow there, and
# tests/test_kernel_tuning.py proves the equivalence), so numerator and
# denominator use the same statistic.  The seed commit (ae5dab9) itself
# timed mean-of-5: 1423.8 / 1096.2 / 115.2 us respectively -- consistent
# with these, but not statistic-compatible with the current harness.
SEED_BASELINE = [
    {"name": "pallas_sq_matmul[interp]", "us_per_call": 1515.0,
     "shape": "128x128x128", "mode": "f32"},
    {"name": "pallas_cpm3_matmul[interp]", "us_per_call": 1011.8,
     "shape": "64x64x64", "mode": "c64"},
    {"name": "pallas_sq_conv[interp]", "us_per_call": 84.9,
     "shape": "L=2048 taps=16", "mode": "f32"},
]


def _print_rows(title, rows):
    print(f"\n# {title}")
    if not rows:
        print("(no rows)")
        return
    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(f"{r[k]:.6g}" if isinstance(r[k], float) else str(r[k])
                       for k in keys))


def build_bench_payload(timing_rows):
    """The perf-trajectory payload: current rows + seed baseline +
    per-kernel speedup (seed_us / current_us) where names match, plus the
    same-process ratio columns (rank-1, im2col, per-call-prep)."""
    seed_by_name = {r["name"]: r for r in SEED_BASELINE}
    by_name = {r["name"]: r for r in timing_rows}
    rank1 = by_name.get("pallas_sq_matmul_rank1[interp]")
    # im2col conv2d rows indexed by shape: every fused conv2d row gets its
    # same-shape, same-process (load-drift-immune) fused-vs-im2col ratio
    im2col_by_shape = {r["shape"]: r for r in timing_rows
                       if r.get("mode") == "f32/im2col"}
    # per-call-prep rows indexed by shape: every prepared-operand row gets
    # its same-shape, same-process prepared-vs-raw amortization ratio
    raw_by_shape = {r["shape"]: r for r in timing_rows
                    if r.get("mode") == "f32/per-call-prep"}
    rows = []
    for r in timing_rows:
        row = dict(r)
        seed = seed_by_name.get(r["name"])
        if seed is not None:
            row["seed_us_per_call"] = seed["us_per_call"]
            row["speedup_vs_seed"] = seed["us_per_call"] / r["us_per_call"]
        if r["name"] == "pallas_sq_matmul[interp]" and rank1 is not None:
            # same-process rank-1 reference: load-drift-immune ratio
            row["speedup_vs_rank1"] = rank1["us_per_call"] / r["us_per_call"]
        im2col = im2col_by_shape.get(r["shape"])
        if r.get("mode") == "f32/fused" and im2col is not None:
            row["speedup_vs_im2col"] = \
                im2col["us_per_call"] / r["us_per_call"]
        raw = raw_by_shape.get(r["shape"])
        if r.get("mode") == "f32/prepared" and raw is not None:
            row["speedup_vs_raw"] = raw["us_per_call"] / r["us_per_call"]
        rows.append(row)
    return {"seed_baseline": SEED_BASELINE, "rows": rows}


def write_bench_json(payload, path="BENCH_kernels.json"):
    """Write a payload built by :func:`build_bench_payload`."""
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"\nwrote {path}")
    return payload


def load_committed(path="BENCH_kernels.json"):
    """The committed trajectory file (the --check comparison baseline),
    read BEFORE --json overwrites it.  None when absent/unreadable."""
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def check_regressions(payload, committed, tol=None):
    """CI bench regression gate (``run.py --check``).

    Returns a list of failure strings (empty = gate passes):

    - any measured row's ``speedup_vs_seed`` below ``1.0 - tol`` --
      ``tol`` comes from ``$BENCH_CHECK_TOL`` (default 0; CI sets a
      fractional slack for its quota-throttled runners -- the slack still
      catches real regressions like the PR 1 sq_conv 0.71x);
    - a ratio row (``speedup_vs_im2col`` / ``speedup_vs_raw``) more than
      20% below its committed BENCH_kernels.json value -- enforced only
      where the committed ratio is DECISIVE (>= 1.5x): same-process
      ratios are load-drift-immune, but near-parity pairs (e.g. the
      unbatched fused-vs-im2col conv, measured 1.0-1.8x across runs)
      genuinely oscillate and stay informational;
    - a route-choice row whose planner decision flipped vs the committed
      file.

    The serving-engine rows are gated separately by
    :func:`benchmarks.serving.check_serving` (prepared-square tokens/s
    >= 1.0x raw-square, square-routed fraction >= 0.9, the guarded
    engine's resilience overhead within tolerance of prepared, the
    paged-attn kernel route within tolerance of gather with identical
    greedy tokens, and SWA window eviction strictly reducing
    peak_blocks_used).
    """
    if tol is None:
        tol = float(os.environ.get("BENCH_CHECK_TOL", "0.0"))
    failures = []
    committed_rows = {r["name"]: r for r in (committed or {}).get("rows", [])}
    for row in payload["rows"]:
        name = row["name"]
        seed_speedup = row.get("speedup_vs_seed")
        if seed_speedup is not None and seed_speedup < 1.0 - tol:
            failures.append(f"{name}: speedup_vs_seed {seed_speedup:.2f} "
                            f"< {1.0 - tol:.2f}")
        prev = committed_rows.get(name)
        if prev is None:
            continue
        for field in ("speedup_vs_im2col", "speedup_vs_raw"):
            cur, old = row.get(field), prev.get(field)
            if cur is not None and old is not None and old >= 1.5 \
                    and cur < 0.8 * old:
                failures.append(f"{name}: {field} {cur:.2f} dropped >20% "
                                f"below committed {old:.2f}")
        if "route" in row and "route" in prev \
                and row["route"] != prev["route"]:
            failures.append(f"{name}: route choice flipped "
                            f"{prev['route']!r} -> {row['route']!r}")
    return failures


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    emit_json = "--json" in argv
    check = "--check" in argv
    committed = load_committed() if check else None

    from benchmarks import gatecost, kernel_timing, ratios, serving, training

    # Timing rows are measured FIRST, while the process is cold: the claim
    # tables below burn ~a minute of sustained compute, and on quota-
    # throttled runners (cgroup cpu-shares) that depresses any timing
    # measured afterwards by 1.5-2x.  Printed in their usual spot below.
    timing_rows = (kernel_timing.matmul_modes()
                   + kernel_timing.pallas_kernels()
                   + kernel_timing.routed_conv2d_rows()
                   + kernel_timing.prepared_rows()
                   + kernel_timing.lm_forward_rows())
    # Serving rows ride directly after the kernel timings: their gated
    # quantity is an interleaved same-process ratio (prepared vs raw
    # tokens/s), so later-phase throttling cannot flip it.  The jitted
    # long-context rows (paged-attn kernel vs gather, SWA eviction
    # footprint) follow -- same-process interleaved ratios as well.
    serving_rows = serving.serving_rows() + serving.long_context_rows()
    # Training rows follow the same discipline: jitted steps, modes
    # interleaved per rep, so the gated square-vs-standard step-time
    # ratio is a same-process quantity.
    training_rows = training.training_rows()

    # --- Paper claim 1: real matmul, eq (6): ratio -> 1 ---
    rows = ratios.real_matmul_ratio()
    _print_rows("eq(6) real matmul squares/multiply (-> 1)", rows)
    assert all(r["exact_match"] for r in rows)

    # --- Paper claim 2: complex matmul with 4 squares, eq (20) ---
    rows = ratios.cpm4_ratio()
    _print_rows("eq(20) CPM4 squares/complex-multiply (-> 4)", rows)
    assert all(r["exact_match"] for r in rows)

    # --- Paper claim 3: complex matmul with 3 squares, eq (36) ---
    rows = ratios.cpm3_ratio()
    _print_rows("eq(36) CPM3 squares/complex-multiply (-> 3)", rows)
    assert all(r["exact_match"] for r in rows)

    # --- Paper claim 4: gate-count savings (squarer ~ multiplier/2) ---
    _print_rows("gate-cost model: MAC/CPM area ratios", gatecost.mac_savings())
    _print_rows("square systolic arrays (paper fig.2)", gatecost.systolic_sweep())
    _print_rows("square tensor cores (paper fig.4/5)", gatecost.tensor_core_sweep())

    # --- Paper conclusion: approximate squaring ---
    from benchmarks import approx
    _print_rows("approximate (truncated) squarers: int8 matmul error vs area",
                approx.approx_matmul_error())
    _print_rows("approximate (bf16) squarers: float matmul error",
                approx.approx_float_error())

    # --- timing microbenches (CSV contract: name,us_per_call,shape,mode) ---
    print("\n# timing (name,us_per_call,shape,mode)")
    for row in timing_rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['shape']},"
              f"{row['mode']}")

    print("\n# serving engine (paged cache, continuous batching; "
          "interp/eager)")
    for row in serving_rows:
        print(f"{row['name']},{row['tokens_per_s']:.2f}tok/s,"
              f"ttft={row['mean_ttft_s'] * 1e3:.0f}ms,"
              f"util={row['mean_block_utilization']:.2f},"
              f"occupancy={row['batch_occupancy']:.2f}"
              + (f",speedup_vs_raw={row['speedup_vs_raw']:.2f}"
                 if "speedup_vs_raw" in row else "")
              + (f",speedup_vs_prepared={row['speedup_vs_prepared']:.2f}"
                 if "speedup_vs_prepared" in row else "")
              + (f",speedup_vs_gather={row['speedup_vs_gather']:.2f}"
                 if "speedup_vs_gather" in row else "")
              + (f",peak_blocks={row['peak_blocks_used']}"
                 if row["name"].startswith("serving_engine_swa") else ""))

    print("\n# training (jitted train step: standard vs square-routed "
          "fwd+bwd)")
    for row in training_rows:
        print(f"{row['name']},{row['us_per_step']:.0f}us/step,"
              f"frac_sq={row['fraction_square']:.2f},"
              f"frac_sq_bwd={row['fraction_square_bwd']:.2f},"
              f"loss={row['loss_last']:.4f}"
              + (f",speedup_vs_standard={row['speedup_vs_standard']:.2f}"
                 if "speedup_vs_standard" in row else ""))

    payload = build_bench_payload(timing_rows)
    serving_payload = serving.build_serving_payload(serving_rows)
    training_payload = training.build_training_payload(training_rows)

    # --- roofline summary from the dry-run, if present ---
    for path in ("dryrun_single_pod.json", "dryrun_multi_pod.json"):
        if os.path.exists(path):
            from repro.roofline.report import build_report, format_table
            print(f"\n# roofline: {path}")
            print(format_table(build_report(path)))

    if check:
        tol = float(os.environ.get("BENCH_CHECK_TOL", "0.0"))
        failures = check_regressions(payload, committed) \
            + serving.check_serving(serving_payload, tol) \
            + training.check_training(training_payload, tol)
        if failures:
            # Do NOT write the regressed payload: it would become the
            # next run's comparison baseline and silently ratchet the
            # gate down.  The committed file stays authoritative.
            print("\nbench regression gate: FAILED"
                  + (" (BENCH_kernels.json left untouched)"
                     if emit_json else ""))
            for f in failures:
                print(f"  - {f}")
            sys.exit(1)
        print("\nbench regression gate: OK")
    if emit_json:
        write_bench_json(payload)
        serving.write_serving_json(serving_payload)
        training.write_training_json(training_payload)

    print("\nbenchmarks: ALL CLAIMS REPRODUCED")


if __name__ == "__main__":
    main()
