"""Benchmark harness: one function per paper claim/table.

Prints ``name,us_per_call,derived`` CSV rows (timing benches) and claim
tables (op-count ratios, gate-cost model).  Roofline benches read the
dry-run JSON if present.
"""
from __future__ import annotations

import json
import os
import sys


def _print_rows(title, rows):
    print(f"\n# {title}")
    if not rows:
        print("(no rows)")
        return
    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(f"{r[k]:.6g}" if isinstance(r[k], float) else str(r[k])
                       for k in keys))


def main() -> None:
    from benchmarks import gatecost, kernel_timing, ratios

    # --- Paper claim 1: real matmul, eq (6): ratio -> 1 ---
    rows = ratios.real_matmul_ratio()
    _print_rows("eq(6) real matmul squares/multiply (-> 1)", rows)
    assert all(r["exact_match"] for r in rows)

    # --- Paper claim 2: complex matmul with 4 squares, eq (20) ---
    rows = ratios.cpm4_ratio()
    _print_rows("eq(20) CPM4 squares/complex-multiply (-> 4)", rows)
    assert all(r["exact_match"] for r in rows)

    # --- Paper claim 3: complex matmul with 3 squares, eq (36) ---
    rows = ratios.cpm3_ratio()
    _print_rows("eq(36) CPM3 squares/complex-multiply (-> 3)", rows)
    assert all(r["exact_match"] for r in rows)

    # --- Paper claim 4: gate-count savings (squarer ~ multiplier/2) ---
    _print_rows("gate-cost model: MAC/CPM area ratios", gatecost.mac_savings())
    _print_rows("square systolic arrays (paper fig.2)", gatecost.systolic_sweep())
    _print_rows("square tensor cores (paper fig.4/5)", gatecost.tensor_core_sweep())

    # --- Paper conclusion: approximate squaring ---
    from benchmarks import approx
    _print_rows("approximate (truncated) squarers: int8 matmul error vs area",
                approx.approx_matmul_error())
    _print_rows("approximate (bf16) squarers: float matmul error",
                approx.approx_float_error())

    # --- timing microbenches (CSV contract: name,us_per_call,derived) ---
    print("\n# timing (name,us_per_call,derived)")
    for row in kernel_timing.matmul_modes() + kernel_timing.pallas_kernels():
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")

    # --- roofline summary from the dry-run, if present ---
    for path in ("dryrun_single_pod.json", "dryrun_multi_pod.json"):
        if os.path.exists(path):
            from repro.roofline.report import build_report, format_table
            print(f"\n# roofline: {path}")
            print(format_table(build_report(path)))

    print("\nbenchmarks: ALL CLAIMS REPRODUCED")


if __name__ == "__main__":
    main()
