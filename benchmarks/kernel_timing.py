"""Kernel wall-time microbenchmarks (CPU interpret mode for Pallas; jnp for
the algebraic paths).  Interpret-mode timings validate correctness cost, not
TPU performance -- TPU projections come from the roofline (§Roofline).

Row contract: every row dict carries ``name``, ``us_per_call``, ``shape``
and ``mode`` (plus optional extras) -- the same fields ``benchmarks/run.py
--json`` writes to ``BENCH_kernels.json`` so kernel speedups are trackable
across PRs.

``time_plan`` is the hook the empirical autotuner
(:func:`repro.kernels.tuning.autotune_matmul`) drives: it times one kernel
call under an explicit tile plan.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, reps=5, warmup=2):
    """Min-of-reps wall time in us (min is robust to scheduler noise on the
    shared CPU runners these benches execute on)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6      # us


def _time_pair(fn_a, fn_b, *, reps=5, warmup=2):
    """Min-of-reps for two thunks with ALTERNATING measurement.

    A long benchmark run progressively throttles on quota-limited
    runners, so timing all of A's reps before B's biases whichever runs
    later -- enough to invert a same-process ratio.  Interleaving the
    reps keeps the A/B ratio load-drift-immune (both see the same
    machine state); used for every prepared-vs-raw pair."""
    for _ in range(warmup):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())
    best_a = best_b = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a * 1e6, best_b * 1e6


def _plan_kwargs(plan):
    """kwargs for kernels.ops wrappers from a TilePlan (or a bare tuple)."""
    if hasattr(plan, "astuple"):
        bm, bn, bk, kc = plan.astuple()
        return dict(bm=bm, bn=bn, bk=bk, kc=kc,
                    pm_layout=getattr(plan, "pm_layout", None))
    bm, bn, bk, kc = plan
    return dict(bm=bm, bn=bn, bk=bk, kc=kc)


def time_plan(kind, m, n, k, dtype, plan, *, reps=3, batch=1):
    """Wall-time one kernel call under an explicit tile plan (autotune hook).

    kind: "sq_matmul" | "cpm3_matmul" | "cpm4_matmul".  ``batch`` > 1
    times the batched (leading-batch-grid-axis) kernel -- sq_matmul only.
    """
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    kwargs = _plan_kwargs(plan)
    if kind == "sq_matmul":
        lead = (batch,) if batch > 1 else ()
        a = jnp.asarray(rng.normal(size=lead + (m, k)).astype(np.dtype(dtype)))
        b = jnp.asarray(rng.normal(size=lead + (k, n)).astype(np.dtype(dtype)))
        fn = lambda a, b: ops.sq_matmul(a, b, **kwargs)
        return _time(fn, a, b, reps=reps)
    if batch > 1:
        raise ValueError(f"batched timing is only supported for sq_matmul, "
                         f"not {kind!r}")
    if kind in ("cpm3_matmul", "cpm4_matmul"):
        x = jnp.asarray((rng.normal(size=(m, k))
                         + 1j * rng.normal(size=(m, k))).astype(np.complex64))
        y = jnp.asarray((rng.normal(size=(k, n))
                         + 1j * rng.normal(size=(k, n))).astype(np.complex64))
        op = getattr(ops, kind)
        fn = lambda x, y: op(x, y, **kwargs)[0]
        return _time(fn, x, y, reps=reps)
    raise ValueError(f"unknown kernel kind {kind!r}")


def time_conv2d_plan(h, w, kh, kw, cin, cout, dtype, plan, *, stride=(1, 1),
                     reps=3, batch=1):
    """Wall-time one fused-conv2d call under an explicit plan (autotune hook).

    ``h``/``w`` are the padded input spatial extents (VALID geometry --
    exactly what :func:`repro.kernels.tuning.plan_conv2d` keys on).
    """
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    lead = (batch,) if batch > 1 else ()
    x = jnp.asarray(rng.normal(size=lead + (cin, h, w)).astype(np.dtype(dtype)))
    wt = jnp.asarray(rng.normal(size=(cout, cin, kh, kw)).astype(np.dtype(dtype)))
    fn = lambda x, wt: ops.sq_conv2d(
        x, wt, stride=stride, bh=plan.bh, bw=plan.bw, bk=plan.bk,
        kc=plan.kc, bf=plan.bf, pm_layout=plan.pm_layout)
    return _time(fn, x, wt, reps=reps)


def prepared_rows():
    """Prepared-operand amortization rows (the paper's weight-stationary
    contract): the same kernel call with the column-operand prep (widen +
    Sb correction + tile padding) done per call vs done ONCE via
    core.prepared.prepare_operand.  Timed under eager/interpret execution,
    where the per-call prep is real work (under jit both trace identically;
    the prepared form is then free via jit caching)."""
    from repro.core.prepared import prepare_operand
    from repro.kernels import ops

    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(256, 256)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(256, 256)).astype(np.float32))
    prep = prepare_operand(b, m_hint=256)
    raw_us, prep_us = _time_pair(lambda: ops.sq_matmul(a, b),
                                 lambda: ops.sq_matmul(a, prep), reps=7)
    # decode-shaped GEMV block: M tiny relative to the (K, N) weight, so
    # the per-call column prep is a first-order cost -- the regime the
    # weight-stationary contract exists for (measured ~1.5x)
    ad = jnp.asarray(rng.normal(size=(8, 1024)).astype(np.float32))
    bd = jnp.asarray(rng.normal(size=(1024, 1024)).astype(np.float32))
    prepd = prepare_operand(bd, m_hint=8)
    rawd_us, prepd_us = _time_pair(lambda: ops.sq_matmul(ad, bd),
                                   lambda: ops.sq_matmul(ad, prepd), reps=7)
    return [
        {"name": "pallas_sq_matmul_raw256[interp]", "us_per_call": raw_us,
         "shape": "256x256x256", "mode": "f32/per-call-prep"},
        {"name": "pallas_sq_matmul_prepared256[interp]",
         "us_per_call": prep_us,
         "shape": "256x256x256", "mode": "f32/prepared"},
        {"name": "pallas_sq_matmul_raw_decode[interp]",
         "us_per_call": rawd_us,
         "shape": "8x1024x1024", "mode": "f32/per-call-prep"},
        {"name": "pallas_sq_matmul_prepared_decode[interp]",
         "us_per_call": prepd_us,
         "shape": "8x1024x1024", "mode": "f32/prepared"},
    ]


def routed_conv2d_rows():
    """Route-planner row: the tiny-K conv2d shape under plain
    ``square_pallas`` mode -- kernels.routing now auto-selects the im2col
    route here (cache-resident patch matrix, K volume 25), closing the
    ROADMAP conv-route-selection item.  The ``route`` field pins the
    choice so run.py --check flags a route flip.  (At B=1 this shape is
    near route-parity in wall clock -- the regime boundary encodes the
    PR 3 tuned trajectory and the patch-blowup asymptotics; per-shape
    measured winners can be pinned via routing.set_route_override.)"""
    from repro.core import conv as conv_core
    from repro.kernels import routing

    rng = np.random.default_rng(1)
    xi = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    wi = jnp.asarray(rng.normal(size=(5, 5)).astype(np.float32))
    # derive the routed geometry from the arrays actually timed (VALID,
    # stride 1), so the recorded route can never drift from the call
    (h, w_), (kh, kw) = xi.shape, wi.shape
    route = routing.select_conv2d_route(h - kh + 1, w_ - kw + 1, kh, kw,
                                        1, 1, dtype=xi.dtype)
    return [
        {"name": "pallas_sq_conv2d_routed[interp]",
         "us_per_call": _time(
             lambda x, w: conv_core.conv2d(x, w, mode="square_pallas"),
             xi, wi, reps=15),
         "shape": f"{h}x{w_} k{kh}x{kw}", "mode": "f32/routed",
         "route": route.name},
    ]


def lm_forward_rows():
    """End-to-end amortization rows: a small-config LM forward + logits
    under ``square_pallas`` (interpret, eager -- each dense/vocab GEMM
    really runs the Pallas kernel), raw params vs
    ``LM.prepare_params`` prepared weights.  Captures the trajectory of
    the whole-datapath amortization win, not just kernel microbenches."""
    import jax.random as jrandom
    from repro.configs.base import ContractionPolicy, ModelConfig
    from repro.models.lm import build_model

    rng = np.random.default_rng(5)
    pol = ContractionPolicy.of(default="square_pallas",
                               attn_scores="standard", attn_pv="standard")
    # short sequence against wide weights: the serving-prefill regime
    # where the per-call weight prep is a first-order cost
    cfg = ModelConfig(name="bench-tiny", family="dense", n_layers=2,
                      d_model=512, n_heads=4, n_kv_heads=4, d_ff=2048,
                      vocab=16384, head_dim=128, dtype="float32",
                      scan_layers=False, remat="none", attn_chunk_q=8,
                      attn_chunk_kv=8, loss_chunk=8, max_seq=64,
                      matmul_mode="square_pallas", contraction_policy=pol)
    model = build_model(cfg)
    params = model.init(jrandom.PRNGKey(0))
    prepared = model.prepare_params(params)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)

    def fwd(p):
        hidden, _, _ = model.forward(p, {"tokens": tokens})
        return model.logits(p, hidden)

    raw_us, prep_us = _time_pair(lambda: fwd(params), lambda: fwd(prepared),
                                 reps=3, warmup=1)
    shape = "L2 d512 ff2048 v16384 s8"
    return [
        {"name": "lm_forward_raw[interp]", "us_per_call": raw_us,
         "shape": shape, "mode": "f32/per-call-prep"},
        {"name": "lm_forward_prepared[interp]", "us_per_call": prep_us,
         "shape": shape, "mode": "f32/prepared"},
    ]


def matmul_modes(m=256, k=256, n=256):
    from repro.core import matmul as M
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    rows = []
    for mode in ("standard", "square_virtual", "square_scan"):
        f = jax.jit(lambda a, b, mode=mode: M.matmul(a, b, mode=mode))
        rows.append({"name": f"matmul[{mode}]", "us_per_call": _time(f, a, b),
                     "shape": f"{m}x{k}x{n}", "mode": mode})
    return rows


def pallas_kernels():
    """The tracked Pallas kernel timings (planner-default tile plans), plus
    a rank-1 reference row (kc=1, "mkn" -- the seed kernels' dataflow) so
    the chunked-vs-rank-1 speedup is measured in-process, immune to
    machine-load drift between benchmark runs."""
    from repro.kernels import ops

    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(2048,)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
    xi = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    wi = jnp.asarray(rng.normal(size=(5, 5)).astype(np.float32))
    # CNN-layer conv2d shape (ROADMAP fused-conv target): 32x32, 64 -> 64
    # channels, 3x3 taps -- fused window streaming vs materialized im2col.
    # Tracked unbatched AND at batch 4: the batched pair is the headline --
    # the im2col route must materialize a B*oh*ow x cin*kh*kw patch matrix
    # (~17 MB at B=4) whose matmul has no cache-resident plan, while the
    # fused kernel runs one batch element per grid step at B=1 efficiency.
    xc = jnp.asarray(rng.normal(size=(64, 32, 32)).astype(np.float32))
    wc = jnp.asarray(rng.normal(size=(64, 64, 3, 3)).astype(np.float32))
    xcb = jnp.asarray(rng.normal(size=(4, 64, 32, 32)).astype(np.float32))
    zx = jnp.asarray((rng.normal(size=(64, 64))
                      + 1j * rng.normal(size=(64, 64))).astype(np.complex64))
    zy = jnp.asarray((rng.normal(size=(64, 64))
                      + 1j * rng.normal(size=(64, 64))).astype(np.complex64))
    # fused-vs-im2col pairs are measured INTERLEAVED (_time_pair): their
    # speedup_vs_im2col ratios feed the --check regression gate, so they
    # must be immune to progressive runner throttling across the bench.
    fused_us, im2col_us = _time_pair(
        lambda: ops.sq_conv2d(xc, wc), lambda: ops.sq_conv2d_im2col(xc, wc),
        reps=8)
    fused_b4_us, im2col_b4_us = _time_pair(
        lambda: ops.sq_conv2d(xcb, wc),
        lambda: ops.sq_conv2d_im2col(xcb, wc), reps=3)
    reps = 15
    return [
        {"name": "pallas_sq_matmul[interp]",
         "us_per_call": _time(ops.sq_matmul, a, b, reps=reps),
         "shape": "128x128x128", "mode": "f32"},
        {"name": "pallas_sq_matmul_rank1[interp]",
         "us_per_call": _time(
             lambda a, b: ops.sq_matmul(a, b, kc=1, pm_layout="mkn"),
             a, b, reps=reps),
         "shape": "128x128x128", "mode": "f32/rank1-ref"},
        {"name": "pallas_cpm3_matmul[interp]",
         "us_per_call": _time(lambda x, y: ops.cpm3_matmul(x, y)[0], zx, zy,
                              reps=reps),
         "shape": "64x64x64", "mode": "c64"},
        {"name": "pallas_cpm4_matmul[interp]",
         "us_per_call": _time(lambda x, y: ops.cpm4_matmul(x, y)[0], zx, zy,
                              reps=reps),
         "shape": "64x64x64", "mode": "c64"},
        {"name": "pallas_sq_conv[interp]",
         "us_per_call": _time(ops.sq_conv, x, w, reps=reps),
         "shape": "L=2048 taps=16", "mode": "f32"},
        # historical row: same name, same 64x64 k5x5 workload as every
        # prior BENCH_kernels.json -- ops.sq_conv2d now routes it through
        # the fused kernel (the mode field records the route change)
        {"name": "pallas_sq_conv2d[interp]",
         "us_per_call": _time(ops.sq_conv2d, xi, wi, reps=reps),
         "shape": "64x64 k5x5", "mode": "f32/fused"},
        {"name": "pallas_sq_conv2d_fused[interp]",
         "us_per_call": fused_us,
         "shape": "32x32x64->64 k3x3", "mode": "f32/fused"},
        {"name": "pallas_sq_conv2d_im2col[interp]",
         "us_per_call": im2col_us,
         "shape": "32x32x64->64 k3x3", "mode": "f32/im2col"},
        {"name": "pallas_sq_conv2d_fused_b4[interp]",
         "us_per_call": fused_b4_us,
         "shape": "b4 32x32x64->64 k3x3", "mode": "f32/fused"},
        {"name": "pallas_sq_conv2d_im2col_b4[interp]",
         "us_per_call": im2col_b4_us,
         "shape": "b4 32x32x64->64 k3x3", "mode": "f32/im2col"},
    ]
