"""Kernel wall-time microbenchmarks (CPU interpret mode for Pallas; jnp for
the algebraic paths).  Interpret-mode timings validate correctness cost, not
TPU performance -- TPU projections come from the roofline (§Roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, reps=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6      # us


def matmul_modes(m=256, k=256, n=256):
    from repro.core import matmul as M
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    rows = []
    for mode in ("standard", "square_virtual", "square_scan"):
        f = jax.jit(lambda a, b, mode=mode: M.matmul(a, b, mode=mode))
        rows.append({"name": f"matmul[{mode}]", "us_per_call": _time(f, a, b),
                     "derived": f"{m}x{k}x{n}"})
    return rows


def pallas_kernels():
    from repro.kernels import ops
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(2048,)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
    zx = jnp.asarray((rng.normal(size=(64, 64)) + 1j * rng.normal(size=(64, 64))).astype(np.complex64))
    zy = jnp.asarray((rng.normal(size=(64, 64)) + 1j * rng.normal(size=(64, 64))).astype(np.complex64))
    return [
        {"name": "pallas_sq_matmul[interp]",
         "us_per_call": _time(ops.sq_matmul, a, b), "derived": "128^3 f32"},
        {"name": "pallas_cpm3_matmul[interp]",
         "us_per_call": _time(lambda x, y: ops.cpm3_matmul(x, y)[0], zx, zy),
         "derived": "64^3 c64"},
        {"name": "pallas_sq_conv[interp]",
         "us_per_call": _time(ops.sq_conv, x, w), "derived": "L=2048 taps=16"},
    ]
