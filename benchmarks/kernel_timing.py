"""Kernel wall-time microbenchmarks (CPU interpret mode for Pallas; jnp for
the algebraic paths).  Interpret-mode timings validate correctness cost, not
TPU performance -- TPU projections come from the roofline (§Roofline).

Row contract: every row dict carries ``name``, ``us_per_call``, ``shape``
and ``mode`` (plus optional extras) -- the same fields ``benchmarks/run.py
--json`` writes to ``BENCH_kernels.json`` so kernel speedups are trackable
across PRs.

``time_plan`` is the hook the empirical autotuner
(:func:`repro.kernels.tuning.autotune_matmul`) drives: it times one kernel
call under an explicit tile plan.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, reps=5, warmup=2):
    """Min-of-reps wall time in us (min is robust to scheduler noise on the
    shared CPU runners these benches execute on)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6      # us


def _plan_kwargs(plan):
    """kwargs for kernels.ops wrappers from a TilePlan (or a bare tuple)."""
    if hasattr(plan, "astuple"):
        bm, bn, bk, kc = plan.astuple()
        return dict(bm=bm, bn=bn, bk=bk, kc=kc,
                    pm_layout=getattr(plan, "pm_layout", None))
    bm, bn, bk, kc = plan
    return dict(bm=bm, bn=bn, bk=bk, kc=kc)


def time_plan(kind, m, n, k, dtype, plan, *, reps=3, batch=1):
    """Wall-time one kernel call under an explicit tile plan (autotune hook).

    kind: "sq_matmul" | "cpm3_matmul" | "cpm4_matmul".  ``batch`` > 1
    times the batched (leading-batch-grid-axis) kernel -- sq_matmul only.
    """
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    kwargs = _plan_kwargs(plan)
    if kind == "sq_matmul":
        lead = (batch,) if batch > 1 else ()
        a = jnp.asarray(rng.normal(size=lead + (m, k)).astype(np.dtype(dtype)))
        b = jnp.asarray(rng.normal(size=lead + (k, n)).astype(np.dtype(dtype)))
        fn = lambda a, b: ops.sq_matmul(a, b, **kwargs)
        return _time(fn, a, b, reps=reps)
    if batch > 1:
        raise ValueError(f"batched timing is only supported for sq_matmul, "
                         f"not {kind!r}")
    if kind in ("cpm3_matmul", "cpm4_matmul"):
        x = jnp.asarray((rng.normal(size=(m, k))
                         + 1j * rng.normal(size=(m, k))).astype(np.complex64))
        y = jnp.asarray((rng.normal(size=(k, n))
                         + 1j * rng.normal(size=(k, n))).astype(np.complex64))
        op = getattr(ops, kind)
        fn = lambda x, y: op(x, y, **kwargs)[0]
        return _time(fn, x, y, reps=reps)
    raise ValueError(f"unknown kernel kind {kind!r}")


def time_conv2d_plan(h, w, kh, kw, cin, cout, dtype, plan, *, stride=(1, 1),
                     reps=3, batch=1):
    """Wall-time one fused-conv2d call under an explicit plan (autotune hook).

    ``h``/``w`` are the padded input spatial extents (VALID geometry --
    exactly what :func:`repro.kernels.tuning.plan_conv2d` keys on).
    """
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    lead = (batch,) if batch > 1 else ()
    x = jnp.asarray(rng.normal(size=lead + (cin, h, w)).astype(np.dtype(dtype)))
    wt = jnp.asarray(rng.normal(size=(cout, cin, kh, kw)).astype(np.dtype(dtype)))
    fn = lambda x, wt: ops.sq_conv2d(
        x, wt, stride=stride, bh=plan.bh, bw=plan.bw, bk=plan.bk,
        kc=plan.kc, bf=plan.bf, pm_layout=plan.pm_layout)
    return _time(fn, x, wt, reps=reps)


def matmul_modes(m=256, k=256, n=256):
    from repro.core import matmul as M
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    rows = []
    for mode in ("standard", "square_virtual", "square_scan"):
        f = jax.jit(lambda a, b, mode=mode: M.matmul(a, b, mode=mode))
        rows.append({"name": f"matmul[{mode}]", "us_per_call": _time(f, a, b),
                     "shape": f"{m}x{k}x{n}", "mode": mode})
    return rows


def pallas_kernels():
    """The tracked Pallas kernel timings (planner-default tile plans), plus
    a rank-1 reference row (kc=1, "mkn" -- the seed kernels' dataflow) so
    the chunked-vs-rank-1 speedup is measured in-process, immune to
    machine-load drift between benchmark runs."""
    from repro.kernels import ops

    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(2048,)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
    xi = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    wi = jnp.asarray(rng.normal(size=(5, 5)).astype(np.float32))
    # CNN-layer conv2d shape (ROADMAP fused-conv target): 32x32, 64 -> 64
    # channels, 3x3 taps -- fused window streaming vs materialized im2col.
    # Tracked unbatched AND at batch 4: the batched pair is the headline --
    # the im2col route must materialize a B*oh*ow x cin*kh*kw patch matrix
    # (~17 MB at B=4) whose matmul has no cache-resident plan, while the
    # fused kernel runs one batch element per grid step at B=1 efficiency.
    xc = jnp.asarray(rng.normal(size=(64, 32, 32)).astype(np.float32))
    wc = jnp.asarray(rng.normal(size=(64, 64, 3, 3)).astype(np.float32))
    xcb = jnp.asarray(rng.normal(size=(4, 64, 32, 32)).astype(np.float32))
    zx = jnp.asarray((rng.normal(size=(64, 64))
                      + 1j * rng.normal(size=(64, 64))).astype(np.complex64))
    zy = jnp.asarray((rng.normal(size=(64, 64))
                      + 1j * rng.normal(size=(64, 64))).astype(np.complex64))
    reps = 15
    return [
        {"name": "pallas_sq_matmul[interp]",
         "us_per_call": _time(ops.sq_matmul, a, b, reps=reps),
         "shape": "128x128x128", "mode": "f32"},
        {"name": "pallas_sq_matmul_rank1[interp]",
         "us_per_call": _time(
             lambda a, b: ops.sq_matmul(a, b, kc=1, pm_layout="mkn"),
             a, b, reps=reps),
         "shape": "128x128x128", "mode": "f32/rank1-ref"},
        {"name": "pallas_cpm3_matmul[interp]",
         "us_per_call": _time(lambda x, y: ops.cpm3_matmul(x, y)[0], zx, zy,
                              reps=reps),
         "shape": "64x64x64", "mode": "c64"},
        {"name": "pallas_cpm4_matmul[interp]",
         "us_per_call": _time(lambda x, y: ops.cpm4_matmul(x, y)[0], zx, zy,
                              reps=reps),
         "shape": "64x64x64", "mode": "c64"},
        {"name": "pallas_sq_conv[interp]",
         "us_per_call": _time(ops.sq_conv, x, w, reps=reps),
         "shape": "L=2048 taps=16", "mode": "f32"},
        # historical row: same name, same 64x64 k5x5 workload as every
        # prior BENCH_kernels.json -- ops.sq_conv2d now routes it through
        # the fused kernel (the mode field records the route change)
        {"name": "pallas_sq_conv2d[interp]",
         "us_per_call": _time(ops.sq_conv2d, xi, wi, reps=reps),
         "shape": "64x64 k5x5", "mode": "f32/fused"},
        {"name": "pallas_sq_conv2d_fused[interp]",
         "us_per_call": _time(ops.sq_conv2d, xc, wc, reps=reps),
         "shape": "32x32x64->64 k3x3", "mode": "f32/fused"},
        {"name": "pallas_sq_conv2d_im2col[interp]",
         "us_per_call": _time(ops.sq_conv2d_im2col, xc, wc, reps=reps),
         "shape": "32x32x64->64 k3x3", "mode": "f32/im2col"},
        {"name": "pallas_sq_conv2d_fused_b4[interp]",
         "us_per_call": _time(ops.sq_conv2d, xcb, wc, reps=5),
         "shape": "b4 32x32x64->64 k3x3", "mode": "f32/fused"},
        {"name": "pallas_sq_conv2d_im2col_b4[interp]",
         "us_per_call": _time(ops.sq_conv2d_im2col, xcb, wc, reps=5),
         "shape": "b4 32x32x64->64 k3x3", "mode": "f32/im2col"},
    ]
