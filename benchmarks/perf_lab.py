"""Perf-iteration lab: run a dry-run cell under named variants and report
the roofline-term deltas.  Drives EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m benchmarks.perf_lab --arch command-r-35b \
        --shape train_4k --variants baseline,remat_dots,square_virtual
"""
from __future__ import annotations

import argparse
import json
import sys

# variant name -> (matmul_mode, config overrides)
VARIANTS = {
    "baseline": (None, {}),
    "square_virtual": ("square_virtual", {}),          # paper mode at scale
    "remat_none": (None, {"remat": "none"}),
    "remat_dots": (None, {"remat": "dots"}),
    "microbatch_32": (None, {"_microbatch": 32}),
    "microbatch_128": (None, {"_microbatch": 128}),
    "no_microbatch": (None, {"_microbatch": 0}),
    "loss_chunk_512": (None, {"loss_chunk": 512}),
    "loss_chunk_8k": (None, {"loss_chunk": 8192}),
    "attn_chunks_4k": (None, {"attn_chunk_q": 4096, "attn_chunk_kv": 2048}),
    "attn_chunks_1k": (None, {"attn_chunk_q": 1024, "attn_chunk_kv": 512}),
    "causal_skip": (None, {"attn_block_skip": True}),
    "zero1": (None, {"_zero1": True}),
    "zero1_skip_dots": (None, {"_zero1": True, "attn_block_skip": True,
                               "remat": "dots"}),
    "skip_dots": (None, {"attn_block_skip": True, "remat": "dots"}),
    "p_bf16": (None, {"attn_p_bf16": True}),
    "skip_pbf16": (None, {"attn_block_skip": True, "attn_p_bf16": True}),
    "combo_all": (None, {"attn_block_skip": True, "attn_p_bf16": True,
                         "_zero1": True}),
    "combo_sq": ("square_virtual", {"attn_block_skip": True,
                                    "attn_p_bf16": True, "_zero1": True}),
    "tp_bf16": (None, {"tp_bf16_reduce": True}),
    "skip_tp": (None, {"attn_block_skip": True, "tp_bf16_reduce": True}),
    "skip_mb128": (None, {"attn_block_skip": True, "_microbatch": 128}),
    "skip_dots_mb128": (None, {"attn_block_skip": True, "remat": "dots",
                               "_microbatch": 128}),
    "skip_dots_mb256": (None, {"attn_block_skip": True, "remat": "dots",
                               "_microbatch": 0}),
    "best_sq": ("square_virtual", {"attn_block_skip": True, "remat": "dots",
                                   "_microbatch": 128, "_zero1": True}),
    "skip_zero1": (None, {"attn_block_skip": True, "_zero1": True}),
    "fold_q": (None, {"attn_fold_q": True}),
    "ragged_pos": (None, {"_lockstep": False}),
    "fold_q_sq": ("square_virtual", {"attn_fold_q": True}),
    "skip_zero1_sq": ("square_virtual", {"attn_block_skip": True,
                                         "_zero1": True}),
}


def run_variant(arch: str, shape: str, name: str, multi_pod: bool = False):
    from repro.launch.dryrun import dryrun_cell
    from repro.roofline.report import roofline_row
    mode, over = VARIANTS[name]
    cell = dryrun_cell(arch, shape, multi_pod=multi_pod, matmul_mode=mode,
                       overrides=dict(over), verbose=False)
    row = roofline_row(cell)
    row["variant"] = name
    row["dot_flops"] = cell["dot_flops_per_device"]
    row["bytes"] = cell["bytes_per_device"]
    row["coll_bytes"] = cell["collective_bytes_total"]
    row["peak_gb"] = cell["peak_bytes_per_device"] / 1e9
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    rows = []
    for name in args.variants.split(","):
        try:
            r = run_variant(args.arch, args.shape, name.strip(),
                            args.multi_pod)
            rows.append(r)
            print(f"{name:16s} compute={r['compute_s']:.4f}s "
                  f"mem={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s "
                  f"bound={r['bottleneck']} MFU={r['roofline_fraction_mfu']:.3f} "
                  f"peak={r['peak_gb']:.1f}GB", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{name:16s} FAILED: {e!r}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2, default=str)


if __name__ == "__main__":
    main()
