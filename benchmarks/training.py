"""Training benchmark: the square-routed train step vs the multiplier
baseline (ROADMAP direction 4, "training as a workload").

One small-but-real LM runs N fixed-seed AdamW steps under three modes:

- ``standard``       -- multiplier-baseline GEMMs (the reference row);
- ``square_virtual`` -- every contraction square-routed through the MXU
                        identity, forward AND backward: the fs_einsum
                        custom VJP re-enters the dispatcher for dL/dx and
                        dL/dW as ``<site>.bwd_x`` / ``<site>.bwd_w``
                        (the gated pair);
- ``square_pallas``  -- the Pallas kernel route (informational on this
                        interpret host; exercises the training-shaped
                        tuning-cache entries so the row runs warning-free).

A fourth row, ``train_step_square_guarded[jit]``, runs the same
square-virtual step through :class:`repro.train.step.GuardedStep` -- the
compiled numerics guard (host-callback finite probes + drain/demote/
re-jit, docs/robustness.md) -- and is gated near unguarded parity with
``guard_trips == 0`` on this clean run: the guard must cost ~nothing
until it fires, and must not perturb the bit trajectory.

Reported per row: steady-state step time (jitted, trace excluded,
interleaved across modes so the gated ratio is immune to runner-load
drift), the fraction of TOTAL train FLOPs square-routed (forward + the
custom-VJP backward), the backward-only square fraction, and the
loss-curve **bit-trajectory hash** over the N steps
(:func:`repro.optim.adamw.tree_fingerprint` of the per-step loss
sequence -- bit-identical across runs on one host, so trajectory drift
across commits is visible in the JSON diff).

The square fractions come from the COMPILED audit
(:func:`repro.core.counting.track_compiled_contractions` over a trace
made under :func:`~repro.core.counting.compiled_audit`): they cover
every executed step of the trajectory, cached-jit executions included.
The bench previously audited only the first (tracing) call -- steps
2..N ran entirely unobserved -- and the old trace-time path on a cached
step still warns-and-zeros (:class:`~repro.core.counting
.EmptyAuditWarning`), which this bench asserts on every run so the
pre-fix blind spot stays pinned.

``BENCH_training.json`` feeds ``run.py --check``: the square-routed step
must hold ``speedup_vs_standard >= 1.0 - tol``, the square row's
backward fraction must stay >= 0.9 (a VJP regression that silently
reroutes backward GEMMs to the multiplier baseline fails here), and the
guarded row must hold near-parity vs the unguarded square row with zero
trips and an identical bit trajectory.
"""
from __future__ import annotations

import dataclasses
import json
import time
import warnings
from typing import Dict, List

import numpy as np

import jax

from repro.configs.base import ContractionPolicy, ModelConfig
from repro.core import counting
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.lm import build_model
from repro.optim import adamw
from repro.train import step as step_mod

TRAINING_JSON = "BENCH_training.json"

# Train-bench model: the serving-bench geometry (qkv/out 256x256, ffn
# 256<->1024, vocab-logits 4096) shrunk to 2 layers so a jitted train
# step -- forward, VJP backward, AdamW -- stays interpret-host friendly.
# The attention softmax path rides the policy split like production
# configs do; everything else (including the loss vocab GEMM and every
# backward contraction) square-routes.
BENCH_POLICY = ContractionPolicy.of(attn_scores="standard",
                                    attn_pv="standard")
BENCH_CFG = ModelConfig(
    name="train-bench", family="dense", n_layers=2, d_model=256,
    n_heads=4, n_kv_heads=4, d_ff=1024, vocab=4096, head_dim=64,
    dtype="float32", scan_layers=False, remat="none", attn_chunk_q=32,
    attn_chunk_kv=32, loss_chunk=32, max_seq=128,
    matmul_mode="square_virtual", contraction_policy=BENCH_POLICY)

BATCH, SEQ = 2, 64          # forward GEMM rows M = BATCH * SEQ = 128
N_STEPS = 4                 # fixed-seed trajectory length (and timing span)
DATA_SEED = 123

# Tolerance floor for the square-vs-standard step-time gate.  On this
# CPU host the virtual-square step pays its O(M*K + K*N) correction
# terms without an MXU to hide them behind (~0.89x standard measured);
# the floor keeps the gate meaningful -- it still catches a step that
# goes catastrophically slow or a backward that stops square-routing --
# while the parity regime stays the TPU (same stance as the serving
# bench's LONG_ROW_TOL_FLOOR; see docs/tuning.md).
TRAIN_ROW_TOL_FLOOR = 0.2

# Floor for the guarded-vs-unguarded parity gate: the clean-path guard
# overhead is the in-graph probe reduces plus one effects_barrier drain
# per step -- host-callback latency the interpret host cannot hide
# (~0.77x unguarded measured here; the floor leaves noise headroom
# while still catching a guard whose happy path goes catastrophic).
GUARDED_ROW_TOL_FLOOR = 0.4

# Modes in the bench: (row key, matmul_mode); the square_guarded row is
# derived from square_virtual via GuardedStep in training_rows().
MODES = (("standard", "standard"),
         ("square_virtual", "square_virtual"),
         ("square_pallas", "square_pallas"))


def _setup(mode: str):
    """(raw step fn, params, opt_state, batches) for one mode.  The raw
    (unjitted) builder output is returned so callers control the jit:
    the timing closure, the compiled-audit closure and the GuardedStep
    wrapper each need their own trace."""
    if mode == "standard":
        cfg = dataclasses.replace(BENCH_CFG, matmul_mode="standard",
                                  contraction_policy=None)
    else:
        cfg = dataclasses.replace(BENCH_CFG, matmul_mode=mode)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.adamw_init(params)
    data = SyntheticLM(DataConfig(global_batch=BATCH, seq_len=SEQ,
                                  vocab=cfg.vocab, seed=DATA_SEED), cfg)
    batches = data.take(N_STEPS)
    raw = step_mod.make_train_step(model, step_mod.TrainConfig())
    return raw, params, opt, batches


def _compiled_fractions(raw, params, opt, batches):
    """Square fractions covering EVERY step of the trajectory (the
    compiled audit: runtime notes fire per execution, cached or not),
    plus a pinned demonstration that the old trace-time audit of a
    cached step warns-and-zeros -- the pre-fix bench reported fractions
    for the tracing call only."""
    with counting.compiled_audit():
        audited = jax.jit(lambda *a: raw(*a))
        p1, o1, _ = audited(params, opt, batches[0])      # trace + run
        jax.block_until_ready(p1)
    with counting.track_compiled_contractions() as ctr:
        p, o = params, opt
        for batch in batches:
            p, o, metrics = audited(p, o, batch)
        jax.block_until_ready(p)

    # the OLD audit path on the (now cached) step: zero notes + warning
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        _, old_ctr = step_mod.audit_step(audited, params, opt, batches[0])
    assert old_ctr.total_mults == 0, \
        "trace-time audit unexpectedly saw a cached-jit execution"
    assert any(issubclass(c.category, counting.EmptyAuditWarning)
               for c in caught), \
        "EmptyAuditWarning pin lost: the trace-time audit of a cached " \
        "step no longer warns"
    return ctr


def _run_steps(step, params, opt, batches):
    """Run the fixed-seed trajectory; returns (losses, final params)."""
    losses = []
    for batch in batches:
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(np.asarray(metrics["loss"])))
    jax.block_until_ready(params)
    return losses, params


def training_rows() -> List[Dict]:
    """Measure the train-step configurations; returns BENCH rows."""
    runs: Dict[str, Dict] = {}
    for key, mode in MODES:
        raw, params, opt, batches = _setup(mode)
        # Compiled audit (separate trace): fractions over the WHOLE
        # trajectory -- every step observed, not just the tracing call.
        ctr = _compiled_fractions(raw, params, opt, batches)
        # Clean timing closure: no baked runtime notes, no probes.
        step = jax.jit(raw)
        p1, o1, _ = step(params, opt, batches[0])       # trace
        jax.block_until_ready(p1)
        losses, final = _run_steps(step, params, opt, batches)
        runs[key] = {
            "step": step, "params": params, "opt": opt, "batches": batches,
            "fraction_square": ctr.fraction_square,
            "fraction_square_bwd": ctr.fraction_square_bwd,
            "bwd_mults": ctr.bwd_mults,
            "losses": losses,
            "loss_traj_hash": adamw.tree_fingerprint(
                np.asarray(losses, np.float32)),
            "params_hash": adamw.tree_fingerprint(final),
        }

    # The guarded row: the SAME square-virtual step under the compiled
    # numerics guard (probes baked into the trace, pending-trip drain
    # after every call).  A clean run must be trip-free and bit-identical
    # to the unguarded square row -- the guard's cost is the probe
    # reduces + one effects_barrier per step, gated near parity below.
    raw_sq, params, opt, batches = _setup("square_virtual")
    guarded = step_mod.GuardedStep(raw_sq, jit=True)
    p1, o1, _ = guarded(params, opt, batches[0])        # trace
    jax.block_until_ready(p1)
    losses_g, final_g = _run_steps(guarded, params, opt, batches)
    runs["square_guarded"] = {
        "step": guarded, "params": params, "opt": opt, "batches": batches,
        "fraction_square": runs["square_virtual"]["fraction_square"],
        "fraction_square_bwd": runs["square_virtual"]["fraction_square_bwd"],
        "bwd_mults": runs["square_virtual"]["bwd_mults"],
        "losses": losses_g,
        "loss_traj_hash": adamw.tree_fingerprint(
            np.asarray(losses_g, np.float32)),
        "params_hash": adamw.tree_fingerprint(final_g),
    }

    # Steady-state step timing on the already-traced closures, modes
    # interleaved per rep so the gated standard/square ratio is a
    # same-process, load-drift-immune quantity.
    keys = [key for key, _ in MODES] + ["square_guarded"]
    best_s = {key: float("inf") for key in keys}
    for _ in range(3):
        for key in keys:
            r = runs[key]
            t0 = time.monotonic()
            _run_steps(r["step"], r["params"], r["opt"], r["batches"])
            dt = (time.monotonic() - t0) / N_STEPS
            best_s[key] = min(best_s[key], dt)

    rows = []
    for key, mode in MODES + (("square_guarded", "square_virtual"),):
        r = runs[key]
        row = {
            "name": f"train_step_{key}[jit]",
            "mode": mode,
            "shape": f"L{BENCH_CFG.n_layers} d{BENCH_CFG.d_model} "
                     f"v{BENCH_CFG.padded_vocab} B{BATCH} S{SEQ}",
            "us_per_step": best_s[key] * 1e6,
            "steps": N_STEPS,
            "loss_first": r["losses"][0],
            "loss_last": r["losses"][-1],
            "losses_finite": bool(np.isfinite(r["losses"]).all()),
            "fraction_square": r["fraction_square"],
            "fraction_square_bwd": r["fraction_square_bwd"],
            "bwd_mults": r["bwd_mults"],
            "loss_traj_hash": r["loss_traj_hash"],
            "params_hash": r["params_hash"],
        }
        if key != "standard":
            row["speedup_vs_standard"] = \
                best_s["standard"] / best_s[key] if best_s[key] else 0.0
        if key == "square_guarded":
            stats = runs["square_guarded"]["step"].stats()
            row["guard_trips"] = stats["guard_trips"]
            row["guard_rejits"] = stats["rejits"]
            row["speedup_vs_unguarded"] = \
                best_s["square_virtual"] / best_s[key] if best_s[key] else 0.0
        rows.append(row)
    return rows


def build_training_payload(rows: List[Dict]) -> Dict:
    return {"rows": rows}


def write_training_json(payload: Dict, path: str = TRAINING_JSON) -> Dict:
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"\nwrote {path}")
    return payload


def check_training(payload: Dict, tol: float) -> List[str]:
    """Regression gate over the training rows (called by run.py --check):

    - the square-routed (``square_virtual``) step must hold
      ``speedup_vs_standard >= 1.0 - tol`` (tol floored at
      :data:`TRAIN_ROW_TOL_FLOOR` -- the interpret-host correction-term
      slack, see the constant's comment) vs the multiplier baseline;
    - the square row must keep >= 0.9 of its TOTAL train FLOPs
      square-routed AND >= 0.9 of its backward volume square-routed
      (``fraction_square_bwd``): a custom-VJP regression that silently
      reroutes dL/dx / dL/dW to the standard path fails here, exactly the
      pre-VJP behavior this bench exists to pin;
    - every row's fixed-seed loss trajectory must be finite, with the
      bit-trajectory hash present (trajectory drift shows as a hash
      change in the committed JSON).

    The guarded row (``train_step_square_guarded[jit]``) is gated on
    three axes: **near-parity** vs the unguarded square row
    (``speedup_vs_unguarded >= 1.0 - max(tol, GUARDED_ROW_TOL_FLOOR)``
    -- the clean-path cost of the baked probes + per-step drain),
    **zero guard trips** on this clean run (a tripping bench means the
    probes are firing on healthy numerics), and a **bit trajectory
    identical** to the unguarded square row (the guard must observe,
    never perturb).

    The ``square_pallas`` row is informational on interpret hosts (same
    near-parity story as the fused conv/paged-attn kernels -- the kernel
    regime is the TPU; see docs/tuning.md) and is NOT time-gated.
    """
    failures = []
    rows = {r["name"]: r for r in payload.get("rows", [])}
    sq = rows.get("train_step_square_virtual[jit]")
    if sq is None:
        failures.append("training: square_virtual row missing")
    else:
        step_tol = max(tol, TRAIN_ROW_TOL_FLOOR)
        ratio = sq.get("speedup_vs_standard", 0.0)
        if ratio < 1.0 - step_tol:
            failures.append(f"training: square_virtual step ratio "
                            f"{ratio:.2f} < {1.0 - step_tol:.2f} vs standard")
        if sq.get("fraction_square", 0.0) < 0.9:
            failures.append(f"training: fraction_square "
                            f"{sq.get('fraction_square', 0.0):.2f} < 0.90")
        if sq.get("fraction_square_bwd", 0.0) < 0.9:
            failures.append(
                f"training: backward square fraction "
                f"{sq.get('fraction_square_bwd', 0.0):.2f} < 0.90 "
                f"(custom-VJP backward not square-routed)")
    g = rows.get("train_step_square_guarded[jit]")
    if g is None:
        failures.append("training: square_guarded row missing")
    else:
        gtol = max(tol, GUARDED_ROW_TOL_FLOOR)
        ratio = g.get("speedup_vs_unguarded", 0.0)
        if ratio < 1.0 - gtol:
            failures.append(f"training: guarded step ratio {ratio:.2f} < "
                            f"{1.0 - gtol:.2f} vs unguarded square")
        if g.get("guard_trips", -1) != 0:
            failures.append(f"training: guarded clean run tripped "
                            f"{g.get('guard_trips')} time(s) (expected 0)")
        if sq is not None and \
                g.get("loss_traj_hash") != sq.get("loss_traj_hash"):
            failures.append("training: guarded loss trajectory diverged "
                            "from the unguarded square row (the guard "
                            "must observe, never perturb)")
    for name, row in rows.items():
        if not row.get("losses_finite", False):
            failures.append(f"training: {name} loss trajectory not finite")
        if not row.get("loss_traj_hash"):
            failures.append(f"training: {name} missing loss_traj_hash")
    return failures
