"""Training benchmark: the square-routed train step vs the multiplier
baseline (ROADMAP direction 4, "training as a workload").

One small-but-real LM runs N fixed-seed AdamW steps under three modes:

- ``standard``       -- multiplier-baseline GEMMs (the reference row);
- ``square_virtual`` -- every contraction square-routed through the MXU
                        identity, forward AND backward: the fs_einsum
                        custom VJP re-enters the dispatcher for dL/dx and
                        dL/dW as ``<site>.bwd_x`` / ``<site>.bwd_w``
                        (the gated pair);
- ``square_pallas``  -- the Pallas kernel route (informational on this
                        interpret host; exercises the training-shaped
                        tuning-cache entries so the row runs warning-free).

Reported per row: steady-state step time (jitted, trace excluded,
interleaved across modes so the gated ratio is immune to runner-load
drift), the fraction of TOTAL train FLOPs square-routed via
``core/counting`` (forward + backward, from the first tracing call), the
backward-only square fraction, and the loss-curve **bit-trajectory
hash** over the N steps (:func:`repro.optim.adamw.tree_fingerprint` of
the per-step loss sequence -- bit-identical across runs on one host, so
trajectory drift across commits is visible in the JSON diff).

``BENCH_training.json`` feeds ``run.py --check``: the square-routed step
must hold ``speedup_vs_standard >= 1.0 - tol`` and the square row's
backward fraction must stay >= 0.9 (a VJP regression that silently
reroutes backward GEMMs to the multiplier baseline fails here).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List

import numpy as np

import jax

from repro.configs.base import ContractionPolicy, ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.lm import build_model
from repro.optim import adamw
from repro.train import step as step_mod

TRAINING_JSON = "BENCH_training.json"

# Train-bench model: the serving-bench geometry (qkv/out 256x256, ffn
# 256<->1024, vocab-logits 4096) shrunk to 2 layers so a jitted train
# step -- forward, VJP backward, AdamW -- stays interpret-host friendly.
# The attention softmax path rides the policy split like production
# configs do; everything else (including the loss vocab GEMM and every
# backward contraction) square-routes.
BENCH_POLICY = ContractionPolicy.of(attn_scores="standard",
                                    attn_pv="standard")
BENCH_CFG = ModelConfig(
    name="train-bench", family="dense", n_layers=2, d_model=256,
    n_heads=4, n_kv_heads=4, d_ff=1024, vocab=4096, head_dim=64,
    dtype="float32", scan_layers=False, remat="none", attn_chunk_q=32,
    attn_chunk_kv=32, loss_chunk=32, max_seq=128,
    matmul_mode="square_virtual", contraction_policy=BENCH_POLICY)

BATCH, SEQ = 2, 64          # forward GEMM rows M = BATCH * SEQ = 128
N_STEPS = 4                 # fixed-seed trajectory length (and timing span)
DATA_SEED = 123

# Tolerance floor for the square-vs-standard step-time gate.  On this
# CPU host the virtual-square step pays its O(M*K + K*N) correction
# terms without an MXU to hide them behind (~0.89x standard measured);
# the floor keeps the gate meaningful -- it still catches a step that
# goes catastrophically slow or a backward that stops square-routing --
# while the parity regime stays the TPU (same stance as the serving
# bench's LONG_ROW_TOL_FLOOR; see docs/tuning.md).
TRAIN_ROW_TOL_FLOOR = 0.2

# Modes in the bench: (row key, matmul_mode, gated?)
MODES = (("standard", "standard"),
         ("square_virtual", "square_virtual"),
         ("square_pallas", "square_pallas"))


def _setup(mode: str):
    """(jitted step, params, opt_state, batches) for one mode."""
    if mode == "standard":
        cfg = dataclasses.replace(BENCH_CFG, matmul_mode="standard",
                                  contraction_policy=None)
    else:
        cfg = dataclasses.replace(BENCH_CFG, matmul_mode=mode)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.adamw_init(params)
    data = SyntheticLM(DataConfig(global_batch=BATCH, seq_len=SEQ,
                                  vocab=cfg.vocab, seed=DATA_SEED), cfg)
    batches = data.take(N_STEPS)
    step = jax.jit(step_mod.make_train_step(model, step_mod.TrainConfig()))
    return step, params, opt, batches


def _run_steps(step, params, opt, batches):
    """Run the fixed-seed trajectory; returns (losses, final params)."""
    losses = []
    for batch in batches:
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(np.asarray(metrics["loss"])))
    jax.block_until_ready(params)
    return losses, params


def training_rows() -> List[Dict]:
    """Measure the three train-step configurations; returns BENCH rows."""
    runs: Dict[str, Dict] = {}
    for key, mode in MODES:
        step, params, opt, batches = _setup(mode)
        # First call traces: audit it -- the counter sees every forward
        # AND custom-VJP backward contraction of one full train step.
        (p1, o1, _), ctr = step_mod.audit_step(step, params, opt, batches[0])
        jax.block_until_ready(p1)
        losses, final = _run_steps(step, params, opt, batches)
        runs[key] = {
            "step": step, "params": params, "opt": opt, "batches": batches,
            "fraction_square": ctr.fraction_square,
            "fraction_square_bwd": ctr.fraction_square_bwd,
            "bwd_mults": ctr.bwd_mults,
            "losses": losses,
            "loss_traj_hash": adamw.tree_fingerprint(
                np.asarray(losses, np.float32)),
            "params_hash": adamw.tree_fingerprint(final),
        }

    # Steady-state step timing on the already-traced closures, modes
    # interleaved per rep so the gated standard/square ratio is a
    # same-process, load-drift-immune quantity.
    best_s = {key: float("inf") for key, _ in MODES}
    for _ in range(3):
        for key, _mode in MODES:
            r = runs[key]
            t0 = time.monotonic()
            _run_steps(r["step"], r["params"], r["opt"], r["batches"])
            dt = (time.monotonic() - t0) / N_STEPS
            best_s[key] = min(best_s[key], dt)

    rows = []
    for key, mode in MODES:
        r = runs[key]
        row = {
            "name": f"train_step_{key}[jit]",
            "mode": mode,
            "shape": f"L{BENCH_CFG.n_layers} d{BENCH_CFG.d_model} "
                     f"v{BENCH_CFG.padded_vocab} B{BATCH} S{SEQ}",
            "us_per_step": best_s[key] * 1e6,
            "steps": N_STEPS,
            "loss_first": r["losses"][0],
            "loss_last": r["losses"][-1],
            "losses_finite": bool(np.isfinite(r["losses"]).all()),
            "fraction_square": r["fraction_square"],
            "fraction_square_bwd": r["fraction_square_bwd"],
            "bwd_mults": r["bwd_mults"],
            "loss_traj_hash": r["loss_traj_hash"],
            "params_hash": r["params_hash"],
        }
        if key != "standard":
            row["speedup_vs_standard"] = \
                best_s["standard"] / best_s[key] if best_s[key] else 0.0
        rows.append(row)
    return rows


def build_training_payload(rows: List[Dict]) -> Dict:
    return {"rows": rows}


def write_training_json(payload: Dict, path: str = TRAINING_JSON) -> Dict:
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"\nwrote {path}")
    return payload


def check_training(payload: Dict, tol: float) -> List[str]:
    """Regression gate over the training rows (called by run.py --check):

    - the square-routed (``square_virtual``) step must hold
      ``speedup_vs_standard >= 1.0 - tol`` (tol floored at
      :data:`TRAIN_ROW_TOL_FLOOR` -- the interpret-host correction-term
      slack, see the constant's comment) vs the multiplier baseline;
    - the square row must keep >= 0.9 of its TOTAL train FLOPs
      square-routed AND >= 0.9 of its backward volume square-routed
      (``fraction_square_bwd``): a custom-VJP regression that silently
      reroutes dL/dx / dL/dW to the standard path fails here, exactly the
      pre-VJP behavior this bench exists to pin;
    - every row's fixed-seed loss trajectory must be finite, with the
      bit-trajectory hash present (trajectory drift shows as a hash
      change in the committed JSON).

    The ``square_pallas`` row is informational on interpret hosts (same
    near-parity story as the fused conv/paged-attn kernels -- the kernel
    regime is the TPU; see docs/tuning.md) and is NOT time-gated.
    """
    failures = []
    rows = {r["name"]: r for r in payload.get("rows", [])}
    sq = rows.get("train_step_square_virtual[jit]")
    if sq is None:
        failures.append("training: square_virtual row missing")
    else:
        step_tol = max(tol, TRAIN_ROW_TOL_FLOOR)
        ratio = sq.get("speedup_vs_standard", 0.0)
        if ratio < 1.0 - step_tol:
            failures.append(f"training: square_virtual step ratio "
                            f"{ratio:.2f} < {1.0 - step_tol:.2f} vs standard")
        if sq.get("fraction_square", 0.0) < 0.9:
            failures.append(f"training: fraction_square "
                            f"{sq.get('fraction_square', 0.0):.2f} < 0.90")
        if sq.get("fraction_square_bwd", 0.0) < 0.9:
            failures.append(
                f"training: backward square fraction "
                f"{sq.get('fraction_square_bwd', 0.0):.2f} < 0.90 "
                f"(custom-VJP backward not square-routed)")
    for name, row in rows.items():
        if not row.get("losses_finite", False):
            failures.append(f"training: {name} loss trajectory not finite")
        if not row.get("loss_traj_hash"):
            failures.append(f"training: {name} missing loss_traj_hash")
    return failures
